"""Static plan verifier (`core.verify`) — the PR 9 acceptance contract:

  * the channel-capacity checker agrees exactly with a brute-force
    producer/consumer simulation on every (block, burst, capacity)
    triple — the SDF liveness bound is neither optimistic nor
    pessimistic;
  * every committed example graph, schedule, and fusion plan is
    accepted; any plan the verifier accepts runs to completion on the
    virtual-clock driver;
  * a decode plan whose feedback-path FIFO is one credit too small is
    rejected *statically*, naming the exact cycle and the minimum
    viable capacity — a plan that previously only failed via runtime
    deadlock diagnostics;
  * rate-changing channels (the jpeg-style MCU edge) are floored at the
    liveness bound by `ChannelSet.for_graph`;
  * donation findings come from `jax.eval_shape`, not runtime errors;
  * a runtime deadlock report cross-references the static findings (or
    says preflight was skipped).
"""
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import ShapeCfg
from repro.configs.tiny import CONFIG as tiny
from repro.core import planner, restructure, verify
from repro.core.stg import STG, Impl, Node, Selection
from repro.core.verify import (EdgeSpec, PlanVerificationError,
                               VerificationReport)
from repro.graphs import jpeg, lm_graph, nbody, streamit
from repro.runtime.pipeline import DecodePipeline
from repro.runtime.pipeline import schedule as sched_mod
from repro.runtime.pipeline.channels import ChannelSet

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ===========================================================================
# channel-capacity analysis vs brute force
# ===========================================================================
def _bruteforce_gated_deadlocks(block: int, burst: int, cap: int) -> bool:
    """Greedy two-actor simulation of one gated bounded edge: the
    producer fires when ``cap - q >= burst``, the consumer when
    ``q >= block``; wedging before the stream drains is a deadlock."""
    total = block * burst * 4                  # a few steady-state periods
    to_produce, to_consume, q = total // burst, total // block, 0
    while to_produce or to_consume:
        progressed = False
        if to_produce and cap - q >= burst:
            q += burst
            to_produce -= 1
            progressed = True
        if to_consume and q >= block:
            q -= block
            to_consume -= 1
            progressed = True
        if not progressed:
            return True
    return False


@settings(max_examples=25)
@given(st.integers(min_value=1, max_value=6),
       st.integers(min_value=1, max_value=6),
       st.integers(min_value=1, max_value=12))
def test_channel_bound_matches_bruteforce(block, burst, cap):
    rep = VerificationReport()
    verify.check_channel_capacities(
        [EdgeSpec("p", "c", cap, block=block, burst=burst)], rep)
    flagged = not rep.ok()
    assert flagged == _bruteforce_gated_deadlocks(block, burst, cap), \
        f"block={block} burst={burst} cap={cap}: " \
        f"checker={'ERROR' if flagged else 'ok'} disagrees with simulation"
    if flagged:
        floor = verify.channel_liveness_floor(block, burst)
        assert rep.errors()[0].min_viable == floor
        assert not _bruteforce_gated_deadlocks(block, burst, floor)


# ===========================================================================
# committed graphs / plans accepted
# ===========================================================================
@pytest.mark.parametrize("build", [jpeg.build_stg, streamit.build_fft,
                                   streamit.build_filterbank,
                                   streamit.build_autocor, nbody.build_stg])
def test_committed_graphs_accepted(build):
    stg = build()
    for cb in (1, 2):
        rep = verify.verify_graph(stg, Selection.fastest(stg),
                                  capacity_blocks=cb)
        assert rep.ok(), rep.render()


def test_planner_plan_accepted():
    from repro.runtime.pipeline import as_selection
    shape = ShapeCfg("verify_plan", 64, 16, "decode")
    plan = planner.plan(tiny, shape, chips=8, max_tp=4)
    stg, _ = lm_graph.build_stg(tiny, shape, max_tp=4)
    rep = verify.verify_graph(stg, as_selection(plan))
    assert rep.ok(), rep.render()


def test_invalid_graph_is_a_finding_not_a_crash():
    """Rate-inconsistent SDF (no repetition vector exists: the two
    parallel a->b channels demand q_a*2 == q_b*3 AND q_a == q_b) comes
    back as a ``graph.invalid`` ERROR, not an exception."""
    stg = STG()
    stg.add_node(Node(name="a", impls=(Impl("x", 1, 1),), out_rates=(2, 1)))
    stg.add_node(Node(name="b", impls=(Impl("x", 1, 1),), in_rates=(3, 1)))
    stg.connect("a", "b", src_port=0, dst_port=0)
    stg.connect("a", "b", src_port=1, dst_port=1)
    rep = verify.verify_graph(stg, Selection.fastest(stg))
    assert any(f.check == "graph.invalid" for f in rep.errors()), \
        rep.render()


# ===========================================================================
# rate-changing edges: the ChannelSet liveness floor
# ===========================================================================
def _mcu_stg() -> STG:
    """A jpeg-shaped rate change: camera emits 6-block MCU bursts, dct
    consumes 4 blocks per firing (the 4:2:0 luma/chroma split)."""
    stg = STG()
    stg.add_node(Node(name="camera", impls=(Impl("cam", 1.0, 1),),
                 out_rates=(6,)))
    stg.add_node(Node(name="dct", impls=(Impl("dct", 1.0, 1),),
                 in_rates=(4,)))
    stg.connect("camera", "dct")
    return stg


def test_rate_changing_channel_floored_at_liveness_bound():
    stg = _mcu_stg()
    cs = ChannelSet.for_graph(stg, capacity_blocks=1)
    fifo = cs[stg.channels[0].key()]
    floor = verify.channel_liveness_floor(4, 6)     # 4 + 6 - gcd = 8
    assert fifo.capacity >= floor, \
        f"cb=1 sizing {fifo.capacity} is below the liveness bound {floor}"
    rep = verify.verify_graph(stg, Selection.fastest(stg),
                              capacity_blocks=1)
    assert not [f for f in rep.errors()
                if f.check.startswith("channel.")], rep.render()
    # an explicitly undersized edge IS flagged, with the exact fix
    rep2 = VerificationReport()
    verify.check_channel_capacities(
        [EdgeSpec("camera", "dct", floor - 1, block=4, burst=6)], rep2)
    assert rep2.errors() and rep2.errors()[0].min_viable == floor


# ===========================================================================
# decode feedback cycle: static rejection end to end
# ===========================================================================
@pytest.fixture(scope="module")
def decode_setup():
    shape = ShapeCfg("verify_decode", 64, 16, "decode")
    plan = planner.plan(tiny, shape, chips=8, max_tp=4)
    stg, _ = lm_graph.build_stg(tiny, shape, max_tp=4)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, tiny.vocab, rng.integers(4, 20)).tolist()
               for _ in range(8)]
    pipe = DecodePipeline(tiny, stg, plan)
    return pipe, prompts


def test_undersized_feedback_rejected_statically(decode_setup):
    """The acceptance bug: one feedback credit short of the live-group
    count used to surface only as a runtime deadlock/overflow.  Now the
    plan is rejected before any op dispatches, naming the cycle, the
    edge, and the minimum viable capacity."""
    pipe, prompts = decode_setup
    with pytest.raises(PlanVerificationError) as ei:
        pipe.serve(prompts, 4, group_size=4, feedback_capacity=1)
    msg = str(ei.value)
    assert "feedback" in msg and "cycle" in msg
    assert "embed" in msg and "head" in msg       # the exact cycle named
    findings = ei.value.findings
    assert any(f.check == "deadlock.feedback-capacity"
               and f.min_viable == 2 for f in findings), findings
    # exactly enough credits is accepted and serves
    res = pipe.serve(prompts, 3, group_size=4, feedback_capacity=2)
    assert all(len(t) == 3 for t in res.tokens)


def test_default_serve_passes_preflight(decode_setup):
    pipe, prompts = decode_setup
    res = pipe.serve(prompts, 3, group_size=4)
    assert all(len(t) == 3 for t in res.tokens)
    assert pipe.last_preflight.ok(), pipe.last_preflight.render()
    assert "donation-cache-contract" in pipe.last_preflight.checks


def test_preflight_escape_hatch(decode_setup):
    pipe, prompts = decode_setup
    ref = pipe.serve(prompts, 3, group_size=4)
    res = pipe.serve(prompts, 3, group_size=4, preflight=False)
    assert res.tokens == ref.tokens


@settings(max_examples=20)
@given(st.integers(min_value=1, max_value=8),
       st.integers(min_value=1, max_value=8),
       st.integers(min_value=2, max_value=5))
def test_undersized_feedback_always_flagged(n_groups, fb_cap, n_stages):
    """Property: the pure analysis flags an undersized feedback stream
    iff capacity < n_groups, always naming the feedback edge and the
    minimum viable capacity (== n_groups)."""
    names = [f"s{i}" for i in range(n_stages)]
    edges = [EdgeSpec(names[i], names[i + 1], 4, label=f"act{i}")
             for i in range(n_stages - 1)]
    edges.append(EdgeSpec(names[-1], names[0], fb_cap, label="feedback",
                          gated=False))
    rep = VerificationReport()
    verify.check_cycles(edges, n_groups, rep)
    undersized = [f for f in rep.errors()
                  if f.check == "deadlock.feedback-capacity"]
    if fb_cap < n_groups:
        assert undersized, f"cap {fb_cap} < {n_groups} groups not flagged"
        assert "feedback" in undersized[0].subject
        assert undersized[0].min_viable == n_groups
    else:
        assert not undersized, rep.render()


# ===========================================================================
# schedules: verifier acceptance == virtual-clock completion
# ===========================================================================
_SCHEDULES = [sched_mod.fill_drain(2, 4), sched_mod.fill_drain(4, 8),
              sched_mod.one_f_one_b(2, 4), sched_mod.one_f_one_b(4, 8),
              sched_mod.interleaved_1f1b(2, 4, 2)]


@settings(max_examples=15)
@given(st.sampled_from(_SCHEDULES),
       st.integers(min_value=1, max_value=3))
def test_accepted_schedule_completes_on_virtual_clock(schedule, cb):
    """Any (schedule, capacity) pair the credit simulation accepts runs
    to completion on the virtual-clock driver; any it rejects wedges
    there.  `schedule_programs` builds cap-``cb`` FIFOs per edge —
    exactly the capacities handed to the verifier."""
    M = schedule.n_model_stages
    caps = [cb] * (M - 1)
    rep = VerificationReport()
    verify.verify_schedule_credits(
        schedule, caps, caps if schedule.trains else [], rep)
    if rep.ok():
        # simulate_schedule raises if the schedule wedges — acceptance
        # means this completes
        run = sched_mod.simulate_schedule(schedule, f_cost=1.0,
                                          capacity_blocks=cb)
        assert run.makespan > 0
    else:
        with pytest.raises(RuntimeError):
            sched_mod.simulate_schedule(schedule, f_cost=1.0,
                                        capacity_blocks=cb)


def test_schedule_consistency_findings():
    sched = sched_mod.fill_drain(4, 8)
    rep = VerificationReport()
    verify.verify_schedule_consistency(sched, n_stages_built=3, n_micro=8,
                                       train=False, report=rep)
    assert any(f.check == "plan.schedule-shape" for f in rep.errors())
    rep2 = VerificationReport()
    verify.verify_schedule_consistency(sched, n_stages_built=4, n_micro=6,
                                       train=True, report=rep2)
    checks = {f.check for f in rep2.errors()}
    assert "plan.schedule-micro" in checks
    assert "plan.schedule-train" in checks


def test_credit_wedge_names_cycle_and_fix():
    """A burst-2 producer into a capacity-1 edge: the producer has no
    credits, the consumer starves — a genuine wait-for cycle.  The wedge
    report names both blockers, the cycle, and the exact capacity bump
    (2) that lets the same op order complete."""
    ops = [
        [verify.SimOp("a0", pushes=((0, 2),))],
        [verify.SimOp("b0", pops=((0, 1),)),
         verify.SimOp("b1", pops=((0, 1),))],
    ]
    wedge = verify.simulate_credit_schedule(ops, [1])
    assert wedge is not None
    reasons = {(r, ei) for _s, _l, r, ei in wedge.blockers}
    assert ("no credits", 0) in reasons and ("starved", 0) in reasons
    assert wedge.cycle, "wait-for cycle missing from the wedge report"
    assert wedge.min_viable == {0: 2}
    text = wedge.describe(["e0"])
    assert "no credits" in text and "e0>=2" in text
    # and the bump it names is real: capacity 2 completes
    assert verify.simulate_credit_schedule(ops, [2]) is None


# ===========================================================================
# fusion legality
# ===========================================================================
def test_fusion_legality_matches_enumerate_fusions():
    names = ["a", "b", "c", "d"]
    heavy = ("b", "c")
    legal = set(restructure.enumerate_fusions(names, heavy=heavy))
    for groups in restructure.enumerate_fusions(names):
        rep = VerificationReport()
        verify.verify_fusion(names, groups, heavy=heavy, report=rep)
        assert rep.ok() == (groups in legal), \
            f"{groups}: verifier and enumerate_fusions disagree"
    # a non-partition is rejected outright
    rep = VerificationReport()
    verify.verify_fusion(names, [("a", "c"), ("b", "d")], heavy=heavy,
                         report=rep)
    assert any(f.check == "plan.fusion-partition" for f in rep.errors())


def test_graph_fusion_roundtrip_on_jpeg():
    stg = jpeg.build_stg()
    sel = Selection.fastest(stg)
    compute = [n for n in stg.topo_order()
               if stg.nodes[n].kind == "compute"]
    for groups in restructure.enumerate_fusions(compute, max_group=3):
        rep = VerificationReport()
        verify.verify_graph_fusion(stg, sel, groups, rep)
        assert rep.ok(), rep.render()


# ===========================================================================
# donation / aliasing
# ===========================================================================
def test_donation_unmatched_leaves_flags_dtype_change():
    import jax
    import jax.numpy as jnp
    aval = {"kv": jax.ShapeDtypeStruct((2, 8), jnp.float32)}

    def good(cache, x):
        return {"kv": cache["kv"] + x}, x

    def bad(cache, x):
        # no output has the donated leaf's (shape, dtype) — the donated
        # f32 buffer cannot be reused anywhere
        return {"kv": cache["kv"].astype(jnp.bfloat16)}, x.sum()

    x = jax.ShapeDtypeStruct((2, 8), jnp.float32)
    assert verify.donation_unmatched_leaves(good, (0,), aval, x) == []
    leaks = verify.donation_unmatched_leaves(bad, (0,), aval, x)
    assert leaks and "float32" in leaks[0]


def test_decode_cache_contract_on_tiny():
    import jax

    from repro.models import lm
    params = lm.init_params(tiny, jax.random.PRNGKey(0))
    stacked = lm.slice_periods(params["layers"], 0, tiny.n_periods)
    rep = VerificationReport()
    verify.verify_decode_cache_contract(tiny, stacked, batch=2, prompt=16,
                                        cap=24, stage="blocks00",
                                        report=rep)
    assert rep.ok(), rep.render()


# ===========================================================================
# runtime deadlock report cross-references the static analysis
# ===========================================================================
def test_deadlock_detail_crossref():
    from repro.runtime.pipeline.engine import Engine
    eng = Engine([], static_report=None)
    detail = eng._deadlock_detail()
    assert "preflight: not run" in detail
    assert eng.diagnostic_bundle()["static_preflight"] == {"ran": False}

    clean = VerificationReport(plan="p")
    clean.ran("cycle-credits")
    eng2 = Engine([], static_report=clean)
    assert "verified deadlock-free" in eng2._deadlock_detail()
    assert eng2.diagnostic_bundle()["static_preflight"]["plan"] == "p"

    dirty = VerificationReport(plan="p")
    dirty.add(verify.ERROR, "deadlock.feedback-capacity", "feedback",
              "short", min_viable=4)
    eng3 = Engine([], static_report=dirty)
    d3 = eng3._deadlock_detail()
    assert "matches" in d3 and "feedback" in d3


# ===========================================================================
# the CI lint gate
# ===========================================================================
def test_stg_lint_cli_fast():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "stg_lint.py"),
         "--fast"], capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 error(s)" in proc.stdout
