"""End-to-end system behaviour: fault tolerance, determinism, serving.

These run the REAL training loop (reduced configs) on CPU — they assert the
pod-scale contracts: restart-from-checkpoint transparency, bitwise data
replay, straggler flagging, serving consistency.
"""
import numpy as np
import pytest

from repro.configs import get_config
from repro.runtime import (FailureInjector, StragglerMonitor,
                           TrainLoopConfig, run_resilient, train_loop)
from repro.runtime.server import LMServer, Request

CFG = get_config("qwen2.5-3b").reduced()


def _loop(tmp, **kw):
    base = dict(steps=10, seq_len=32, global_batch=4, ckpt_dir=str(tmp),
                ckpt_interval=4, log_interval=1, warmup=4, lr=1e-3)
    base.update(kw)
    return TrainLoopConfig(**base)


# ----------------------------------------------------------- training -----
def test_crash_restart_is_transparent(tmp_path):
    """Same final loss with and without a mid-run crash: the failure is
    invisible in the training curve (checkpoint + deterministic replay)."""
    clean = train_loop(CFG, _loop(tmp_path / "clean"))
    failed = run_resilient(
        CFG, _loop(tmp_path / "fail",
                   failures=FailureInjector({6: "crash"})),
        max_restarts=2)
    assert failed["restarts"] == 1
    assert failed["final_step"] == clean.final_step == 10
    # bitwise-identical loss trajectory from the restored step on (the
    # crashed incarnation's partial log is discarded by design)
    overlap = set(clean.losses) & set(failed["losses"])
    assert len(overlap) >= 4
    for s in overlap:
        assert abs(failed["losses"][s] - clean.losses[s]) < 1e-6


def test_two_crashes_still_complete(tmp_path):
    out = run_resilient(
        CFG, _loop(tmp_path, failures=FailureInjector({3: "crash", 7: "crash"})),
        max_restarts=3)
    assert out["restarts"] == 2
    assert out["final_step"] == 10


def test_crash_before_first_checkpoint_restarts_from_scratch(tmp_path):
    out = run_resilient(
        CFG, _loop(tmp_path, failures=FailureInjector({2: "crash"})),
        max_restarts=1)
    assert out["final_step"] == 10


def test_too_many_failures_raises(tmp_path):
    from repro.runtime.failures import SimulatedNodeFailure
    with pytest.raises(SimulatedNodeFailure):
        run_resilient(
            CFG, _loop(tmp_path,
                       failures=FailureInjector({3: "crash", 5: "crash"})),
            max_restarts=1)


def test_seed_determinism(tmp_path):
    a = train_loop(CFG, _loop(tmp_path / "a", seed=11))
    b = train_loop(CFG, _loop(tmp_path / "b", seed=11))
    c = train_loop(CFG, _loop(tmp_path / "c", seed=12))
    assert a.losses == b.losses
    assert a.losses != c.losses


def test_straggler_flagged_and_median_stable(tmp_path):
    mon = StragglerMonitor(threshold=3.0)
    train_loop(CFG, _loop(tmp_path, steps=12,
                          failures=FailureInjector({8: "stall:0.6"}),
                          straggler=mon))
    assert [e.step for e in mon.events] == [8]
    assert mon.median < 0.3          # stall did not poison the median


def test_loss_decreases_on_bigram(tmp_path):
    s = train_loop(CFG, _loop(tmp_path, steps=40, ckpt_interval=0,
                              lr=3e-3, warmup=10))
    first = s.losses[min(s.losses)]
    assert s.final_loss < first - 0.1


# ------------------------------------------------------------ serving -----
def test_server_greedy_deterministic():
    srv1 = LMServer(CFG, max_batch=2, seed=0)
    srv2 = LMServer(CFG, max_batch=2, seed=0)
    reqs = [Request(0, [5, 6, 7], max_new=6), Request(1, [9, 10], max_new=6)]
    o1 = srv1.serve(list(reqs))
    o2 = srv2.serve(list(reqs))
    assert [c.tokens for c in o1] == [c.tokens for c in o2]


def test_server_batch_independence():
    """A request's greedy completion must not depend on its batch-mates
    (right-aligned prompts + causal masking)."""
    srv = LMServer(CFG, max_batch=4, seed=0)
    solo = srv.serve([Request(0, [5, 6, 7], max_new=5)])[0]
    batched = srv.serve([Request(0, [5, 6, 7], max_new=5),
                         Request(1, [11, 12, 13, 14], max_new=5),
                         Request(2, [3], max_new=5)])[0]
    assert solo.tokens == batched.tokens


def test_server_stats_accounting():
    srv = LMServer(CFG, max_batch=4, seed=0)
    outs = srv.serve([Request(i, [2 + i, 3, 4], max_new=4) for i in range(6)])
    assert srv.stats.requests == 6
    assert srv.stats.rounds == 2
    assert srv.stats.decode_tokens == sum(len(c.tokens) for c in outs)
    s = srv.stats.summary()
    assert s["decode_tok_per_s"] > 0
