"""Gradient compression: quantization contracts, ring correctness (8 fake
devices via subprocess), error-feedback convergence."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.optim.compress import (dequantize_int8, ef_compress,
                                  quantize_int8)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_quantize_roundtrip_error_bound(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (257,)) * 10
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) / 2 + 1e-6   # half-ULP of the scale


def test_ef_contract_exact():
    """dequant(q) + new_err == x + err, exactly (in f32)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (64,))
    err = jax.random.normal(jax.random.PRNGKey(1), (64,)) * 0.01
    (q, s), new_err = ef_compress(x, err)
    lhs = dequantize_int8(q, s) + new_err
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(x + err),
                               rtol=0, atol=1e-6)


def test_ef_sgd_converges_like_uncompressed():
    """Toy quadratic: EF-compressed gradient steps reach the optimum."""
    A = jnp.diag(jnp.linspace(0.5, 3.0, 16))
    b = jnp.arange(16.0) / 8

    def grad(w):
        return A @ w - b

    w_ref = jnp.zeros(16)
    w_c = jnp.zeros(16)
    err = jnp.zeros(16)
    for _ in range(300):
        w_ref = w_ref - 0.1 * grad(w_ref)
        (q, s), err = ef_compress(grad(w_c), err)
        w_c = w_c - 0.1 * dequantize_int8(q, s)
    opt = jnp.linalg.solve(A, b)
    assert float(jnp.linalg.norm(w_ref - opt)) < 1e-3
    assert float(jnp.linalg.norm(w_c - opt)) < 1e-2   # EF keeps convergence


_RING_CHECK = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    import sys
    sys.path.insert(0, "src")
    from repro.optim.compress import (CompressionState, compressed_mean,
                                      make_compressed_sync)

    mesh = jax.make_mesh((8,), ("data",))
    n = 8
    rng = np.random.default_rng(0)
    local = rng.normal(size=(8, 4096)).astype(np.float32)

    # 1. raw ring mean vs exact
    def body(x):
        return compressed_mean(x[0], "data", n)[None]
    f = jax.jit(shard_map(body, mesh=mesh, in_specs=P("data"),
                          out_specs=P("data"), check_rep=False))
    got = np.asarray(f(local))
    want = local.mean(axis=0)
    for r in range(8):
        err = np.abs(got[r] - want)
        # two quantization stages; scale ~ max|x|/127
        assert err.max() < 0.15, err.max()

    # 2. EF sync: averaged over steps, the quantization error vanishes
    sync = make_compressed_sync(mesh, "data")
    g = {"w": jnp.asarray(local)}
    st = CompressionState.init({"w": jnp.zeros(4096)}, 8)
    acc = np.zeros(4096)
    steps = 30
    for i in range(steps):
        synced, st = sync(g, st)
        acc += np.asarray(synced["w"][0])
    drift = np.abs(acc / steps - want).max()
    assert drift < 0.02, drift          # EF removes the bias
    print("RING_OK", err.max(), drift)
""")


def test_ring_mean_and_ef_sync_8dev():
    """Run the ring on 8 simulated devices in a subprocess (device count
    must be set before jax initialises)."""
    r = subprocess.run([sys.executable, "-c", _RING_CHECK],
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "RING_OK" in r.stdout
