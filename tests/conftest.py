"""Test-session bootstrap: fall back to the degenerate hypothesis shim.

The real ``hypothesis`` (requirements-dev.txt) is preferred; on a clean
environment the shim in ``_hypothesis_compat`` keeps the suite collecting
and running with fixed seeded examples instead of failing at import time.
"""
import pathlib
import sys

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
    import _hypothesis_compat
    _hypothesis_compat.install()
