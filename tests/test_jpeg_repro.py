"""Reproduction of the paper's JPEG experiment (§III.B, Tables 1-2).

Published-table notes (see EXPERIMENTS.md §Reproduction):
  * ILP totals reproduce at v_tgt = 1 and 4 to <1%; the v=2 row's published
    overhead (5376) is anomalous (its own Eq. 9 cannot produce it and it is
    2x the v=4 row for 2x the replicas under any tree model we tried).
  * The published Encoding replica column is 2x off against the paper's own
    totals for v >= 2 (totals require nr = 512/v).
  * Heuristic totals: we match v=8 exactly and find slightly better points
    than published for v in {1, 2, 4} (the published heuristic is itself a
    heuristic; ours explores the same move set).
"""
import pytest

from repro.core import heuristic, ilp
from repro.core.fork_join import JPEG_CALIBRATED
from repro.core.throughput import analyze
from repro.graphs.jpeg import TABLE2_TOTALS, build_stg


@pytest.fixture(scope="module")
def g():
    return build_stg()


@pytest.mark.parametrize("v,rel", [(1, 0.01), (4, 0.01)])
def test_ilp_totals_match_published(g, v, rel):
    res = ilp.min_area(g, v, JPEG_CALIBRATED)
    pub = TABLE2_TOTALS[v][0]
    assert res.feasible
    assert abs(res.total_area - pub) / pub < rel


@pytest.mark.parametrize("v", [1, 2, 4, 8])
def test_ilp_selects_single_copies_plus_encoder_replicas(g, v):
    """Table 2: ILP picks one copy of the matching CC/DCT/Quant version and
    512/v encoder replicas."""
    res = ilp.min_area(g, v, JPEG_CALIBRATED)
    assert res.selection.choices["encode"] == ("v1", 512 // v)
    for mod in ("color", "dct", "quant"):
        impl, nr = res.selection.choices[mod]
        assert nr == 1
        assert g.nodes[mod].impl(impl).ii <= v


@pytest.mark.parametrize("v", [1, 2, 4, 8])
def test_heuristic_beats_ilp(g, v):
    """The paper's headline: combining gives the heuristic a big area win
    (37% at v=2 against the published ILP)."""
    ri = ilp.min_area(g, v, JPEG_CALIBRATED)
    rh = heuristic.min_area(g, v, JPEG_CALIBRATED)
    assert rh.feasible and ri.feasible
    assert rh.total_area <= ri.total_area * 0.80  # >= 20% saving everywhere
    # against the PUBLISHED ILP totals the saving is >= 26%
    assert rh.total_area <= TABLE2_TOTALS[v][0] * 0.74


@pytest.mark.parametrize("v", [1, 2, 4, 8])
def test_heuristic_at_least_as_good_as_published(g, v):
    rh = heuristic.min_area(g, v, JPEG_CALIBRATED)
    assert rh.total_area <= TABLE2_TOTALS[v][1] + 1e-6


def test_heuristic_v8_exactly_published(g):
    rh = heuristic.min_area(g, 8, JPEG_CALIBRATED)
    assert rh.total_area == 1736
    assert rh.overhead_area == 0  # all fans within nf=4 (published: 0)


@pytest.mark.parametrize("v", [1, 2, 4, 8])
def test_solutions_meet_throughput_target(g, v):
    for solver in (ilp.min_area, heuristic.min_area):
        res = solver(g, v, JPEG_CALIBRATED)
        assert analyze(g, res.selection).v_app <= v + 1e-9


def test_area_mode_inverts_throughput_mode(g):
    """Feeding mode-2 results' area back into mode 1 recovers >= throughput."""
    for v in (1, 2, 4, 8):
        rh = heuristic.min_area(g, v, JPEG_CALIBRATED)
        back = heuristic.max_throughput(g, rh.total_area, JPEG_CALIBRATED)
        assert back.feasible
        assert back.v_app <= v + 1e-9
        ri = ilp.min_area(g, v, JPEG_CALIBRATED)
        backi = ilp.max_throughput(g, ri.total_area, JPEG_CALIBRATED)
        assert backi.feasible and backi.v_app <= v + 1e-9
