"""Self-healing pipelines (runtime/failures + engine failover + health +
elastic serving rescale).

Acceptance contract:
  * killing any replica at any op index leaves both clock drivers at
    quiescent invariants — every FIFO back at full capacity, reorder
    buffers empty, all results delivered in order (hypothesis);
  * a replica fault mid-flight replays the lost ops onto survivors
    under their ORIGINAL sequence numbers (the reorder hole fills, the
    outstanding credit is consumed) — wall-clock engine, both overlap
    modes;
  * decode serving survives an injected replica crash with **bitwise
    token parity** against a fault-free serve, and records the typed
    failover evidence (result + trace + metrics);
  * a fault with no survivors — single-replica stage, or a program
    without a failover hook (the training pipeline) — escalates to a
    structured `PipelineFailure` carrying the diagnostic bundle;
  * injected stalls drive the straggler -> HealthController loop: the
    slow replica is flagged, its groups migrate to healthy peers, and
    repeated strikes produce `planner.replan(measured_ratio=)` advice;
  * an admission-paused serve resumes on a re-planned pipeline
    (`elastic.rescale_serving`) with zero dropped requests and bitwise
    token parity — caches transferred when stage spans match, rebuilt
    by deterministic replay when they don't;
  * `FailureInjector`/`ReplicaFaultPlan` re-arm across incarnations and
    `StragglerMonitor` re-warms after a restart (regression tests).
"""
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.runtime.failures import (FailureInjector, PipelineFailure,
                                    ReplicaFault, ReplicaFaultPlan,
                                    ReplicaFaultSpec, SimulatedNodeFailure)
from repro.runtime.pipeline import (DecodePipeline, Engine, Fifo,
                                    HealthController, MetricsRegistry, Op,
                                    Tracer, as_selection, registry_from_trace,
                                    run_event_loop)
from repro.runtime.straggler import StragglerMonitor


# ===========================================================================
# synthetic replicated chain: src -> work(xR) -> sink, failover on work
# ===========================================================================
def _t(driver):
    return driver.now if driver.virtual else time.perf_counter()


class _Src:
    n_replicas = 1

    def __init__(self, fin, m):
        self.name = "src"
        self.fin = fin
        self.m = m
        self.i = 0

    def pending(self):
        return self.m - self.i

    def peek(self):
        if self.i >= self.m:
            return None
        return Op(stage=0, kind="S", seq=self.i, rep=0)

    def ready(self, op, count_stall=False):
        if self.fin.can_push(1):
            return 0.0
        self.wait_reason = ("credit", self.fin)
        return None

    def dispatch(self, op, driver):
        self.fin.reserve(1)
        self.i += 1
        return (lambda seq=op.seq: seq * 10), ()

    def retire(self, op, result, driver):
        t = _t(driver)
        driver.ordered_push(self.fin, op.seq, result, t)
        driver.wake("work")
        return t

    def describe(self):
        return f"src: {self.i}/{self.m}"


class _Work:
    """The replicated stage under test: routes op seq -> surviving
    replica, saves a ``recover`` payload at dispatch, and replays lost
    ops under their original seq (no new pop, no new reservation — the
    originals are outstanding)."""

    def __init__(self, fin, fout, m, n_replicas):
        self.name = "work"
        self.n_replicas = n_replicas
        self.fin = fin
        self.fout = fout
        self.m = m
        self.i = 0
        self.dead: set = set()
        self.redo: list = []          # (seq, payload), original seqs
        self.crash_at: int | None = None   # op body raises at this seq once
        self._crashed = False

    def rep_of(self, seq):
        alive = [r for r in range(self.n_replicas) if r not in self.dead]
        return alive[seq % len(alive)]

    def pending(self):
        return (self.m - self.i) + len(self.redo)

    def peek(self):
        if self.redo:
            return Op(stage=1, kind="W", seq=self.redo[0][0],
                      rep=self.rep_of(self.redo[0][0]))
        if self.i >= self.m:
            return None
        return Op(stage=1, kind="W", seq=self.i, rep=self.rep_of(self.i))

    def ready(self, op, count_stall=False):
        if self.redo:
            return 0.0                # payload in hand, credit outstanding
        if not len(self.fin):
            self.wait_reason = ("starve", self.fin)
            return None
        if not self.fout.can_push(1):
            self.wait_reason = ("credit", self.fout)
            return None
        return 0.0

    def dispatch(self, op, driver):
        if self.redo and self.redo[0][0] == op.seq:
            _, payload = self.redo.pop(0)
        else:
            ((_seq, payload),) = self.fin.pop_hold(1)
            op.releases.append((self.fin, 1))
            self.fout.reserve(1)
            self.i += 1
        op.recover = (op.seq, payload)
        seq, rep = op.seq, op.rep

        def body():
            if self.crash_at == seq and not self._crashed:
                self._crashed = True
                raise ReplicaFault(f"injected body fault at op {seq}",
                                   stage=self.name, replica=rep)
            return payload * 2

        return body, ()

    def retire(self, op, result, driver):
        t = _t(driver)
        driver.ordered_push(self.fout, op.seq, result, t)
        driver.wake("src", "sink")
        return t

    def fail_replica(self, rep, driver, lost):
        self.dead.add(rep)
        if len(self.dead) >= self.n_replicas:
            raise PipelineFailure(
                f"stage {self.name}: no surviving replicas",
                stage=self.name, replica=rep)
        for op in lost:
            self.redo.append(op.recover)
        self.redo.sort()

    def describe(self):
        return f"work: {self.i}/{self.m} redo={len(self.redo)}"


class _Sink:
    n_replicas = 1

    def __init__(self, fout, m):
        self.name = "sink"
        self.fout = fout
        self.m = m
        self.i = 0
        self.out: list = []

    def pending(self):
        return self.m - self.i

    def peek(self):
        if self.i >= self.m:
            return None
        return Op(stage=2, kind="K", seq=self.i, rep=0)

    def ready(self, op, count_stall=False):
        if len(self.fout):
            return 0.0
        self.wait_reason = ("starve", self.fout)
        return None

    def dispatch(self, op, driver):
        (pair,) = self.fout.pop(1)
        self.i += 1
        return (lambda p=pair: p), ()

    def retire(self, op, result, driver):
        self.out.append(result)
        driver.wake("work")
        return _t(driver)

    def describe(self):
        return f"sink: {self.i}/{self.m}"


def _chain(m, n_replicas, cap=2):
    fin = Fifo(block=1, capacity_blocks=cap)
    fout = Fifo(block=1, capacity_blocks=cap)
    src = _Src(fin, m)
    work = _Work(fin, fout, m, n_replicas)
    sink = _Sink(fout, m)
    return [src, work, sink], fin, fout, sink


def _assert_quiescent(driver, fin, fout, sink, m):
    assert sink.out == [(i, i * 20) for i in range(m)], sink.out
    assert fin.free == fin.capacity, \
        f"fin leaked slots: free {fin.free}/{fin.capacity}"
    assert fout.free == fout.capacity, \
        f"fout leaked slots: free {fout.free}/{fout.capacity}"
    assert driver.reorder_occupancy() == 0


@settings(max_examples=25, deadline=None)
@given(m=st.integers(2, 10), n_rep=st.integers(2, 3),
       rep_idx=st.integers(0, 2), at=st.integers(1, 10), cap=st.integers(1, 3))
def test_kill_any_replica_any_op_quiesces_on_both_drivers(
        m, n_rep, rep_idx, at, cap):
    """The core invariant, wall vs virtual parity style: whatever
    (replica, op index) the crash lands on, both drivers drain to the
    same in-order results with every credit returned and no reorder
    residue.  (A trigger past the replica's dispatch count simply never
    fires — the fault-free run must satisfy the same invariants.)"""
    rep = rep_idx % n_rep

    programs, fin, fout, sink = _chain(m, n_rep, cap)
    inj = ReplicaFaultPlan(faults=[ReplicaFaultSpec("work", rep, at)])
    eng = Engine(programs, overlap=False, injector=inj)
    eng.run()
    _assert_quiescent(eng, fin, fout, sink, m)
    wall_fired = inj.fired
    wall_out = list(sink.out)

    programs, fin, fout, sink = _chain(m, n_rep, cap)
    inj = ReplicaFaultPlan(faults=[ReplicaFaultSpec("work", rep, at)])
    loop_stats = None
    from repro.runtime.pipeline.engine import EventLoop
    loop = EventLoop({p.name: p for p in programs}, injector=inj)
    loop_stats = loop.run()
    _assert_quiescent(loop, fin, fout, sink, m)
    assert sink.out == wall_out
    assert inj.fired == wall_fired          # same op coordinate, same drill
    if wall_fired:
        assert len(loop_stats.failovers) == wall_fired


@pytest.mark.parametrize("overlap", [False, True])
def test_inflight_op_replays_under_original_seq(overlap):
    """A ReplicaFault raised from a dispatched op body: the engine aborts
    the whole replica, the lost op replays from its ``recover`` payload
    under the original seq, and the stream heals — replayed_ops >= 1
    distinguishes this from the dispatch-boundary path."""
    m, n_rep = 8, 2
    programs, fin, fout, sink = _chain(m, n_rep)
    programs[1].crash_at = 3
    eng = Engine(programs, overlap=overlap, workers=4)
    res = eng.run()
    _assert_quiescent(eng, fin, fout, sink, m)
    assert len(res.failovers) == 1
    fo = res.failovers[0]
    assert (fo["stage"], fo["kind"]) == ("work", "crash")
    assert fo["replayed_ops"] >= 1
    assert fo["recovery_s"] >= 0.0
    assert programs[1].dead == {fo["replica"]}


def test_no_survivors_escalates_structured_on_both_drivers():
    for wall in (True, False):
        programs, fin, fout, sink = _chain(4, 1)
        inj = ReplicaFaultPlan.parse("work:r0@op2=crash")
        with pytest.raises(PipelineFailure) as ei:
            if wall:
                Engine(programs, overlap=False, injector=inj).run()
            else:
                run_event_loop({p.name: p for p in programs}, injector=inj)
        e = ei.value
        assert (e.stage, e.replica) == ("work", 0)
        assert e.reason
        assert "schedule" in e.diagnostics
        assert "reorder_occupancy" in e.diagnostics
        assert "work" in e.describe()


def test_virtual_clock_records_skipped_stalls():
    """The virtual clock has no host time to burn: a stall spec is
    recorded as skipped, execution is unchanged."""
    programs, fin, fout, sink = _chain(5, 2)
    inj = ReplicaFaultPlan.parse("work:r1@op1=stall:0.5x99")
    stats = run_event_loop({p.name: p for p in programs}, injector=inj)
    _assert_quiescent_loopless(fin, fout, sink, 5)
    assert stats.skipped_faults
    assert all(k.startswith("stall:") for _, _, k in stats.skipped_faults)
    assert not stats.failovers


def _assert_quiescent_loopless(fin, fout, sink, m):
    assert sink.out == [(i, i * 20) for i in range(m)]
    assert fin.free == fin.capacity and fout.free == fout.capacity


def test_wall_clock_stall_burns_host_time():
    programs, fin, fout, sink = _chain(4, 2)
    inj = ReplicaFaultPlan.parse("work:r0@op1=stall:0.05x2")
    eng = Engine(programs, overlap=False, injector=inj)
    t0 = time.perf_counter()
    eng.run()
    assert time.perf_counter() - t0 >= 0.1       # two stalled firings
    _assert_quiescent(eng, fin, fout, sink, 4)
    assert inj.fired == 2                        # repeat budget honored


# ===========================================================================
# decode serving: failover with bitwise token parity
# ===========================================================================
@pytest.fixture(scope="module")
def chaos_setup():
    from repro.configs.base import ShapeCfg
    from repro.configs.tiny import CONFIG as tiny
    from repro.core import planner
    from repro.graphs import lm_graph

    shape = ShapeCfg("chaos_test", 64, 16, "decode")
    plan = planner.plan(tiny, shape, chips=8, max_tp=4)
    stg, _ = lm_graph.build_stg(tiny, shape, max_tp=4)
    sel = as_selection(plan)
    # force two replicas on the first period's block nodes so stage
    # blocks00 has a survivor to fail over onto
    L = len(tiny.block_pattern)
    for n in stg.topo_order():
        if n.startswith("block") and int(n[5:]) < L:
            sel.set(n, sel.choices[n][0], 2)
    pipe = DecodePipeline(tiny, stg, sel)
    assert len(pipe.stage_devices[pipe.stage_names.index("blocks00")]) == 2
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, tiny.vocab, rng.integers(4, 20)).tolist()
               for _ in range(8)]
    ref = pipe.serve(prompts, 12, group_size=4)
    return tiny, stg, plan, pipe, prompts, ref


@pytest.mark.parametrize("spec", ["blocks00:r1@tok6=crash",
                                  "blocks00:r0@op3=crash"])
def test_decode_failover_bitwise_token_parity(chaos_setup, spec):
    _, _, _, pipe, prompts, ref = chaos_setup
    inj = ReplicaFaultPlan.parse(spec)
    tr = Tracer()
    res = pipe.serve(prompts, 12, group_size=4, injector=inj, tracer=tr)
    assert inj.fired == 1
    assert res.tokens == ref.tokens          # bitwise: nothing was lost
    assert len(res.failovers) == 1
    fo = res.failovers[0]
    assert fo["stage"] == "blocks00" and fo["kind"] == "crash"
    assert fo["recovery_s"] >= 0.0
    # evidence lands in the trace and the metrics registry too
    assert tr.failovers and tr.failovers[0][0] == "blocks00"
    reg = registry_from_trace(tr)
    assert reg.counter("pipeline.failovers", stage="blocks00",
                       replica=str(fo["replica"])).value == 1
    assert reg.find("pipeline.recovery_s")


def test_decode_failover_serial_engine_parity(chaos_setup):
    _, _, _, pipe, prompts, ref = chaos_setup
    inj = ReplicaFaultPlan.parse("blocks00:r1@tok6=crash")
    res = pipe.serve(prompts, 12, group_size=4, injector=inj, overlap=False)
    assert inj.fired == 1
    assert res.tokens == ref.tokens
    assert len(res.failovers) == 1


def test_decode_single_replica_fault_escalates(chaos_setup):
    _, _, _, pipe, prompts, _ = chaos_setup
    inj = ReplicaFaultPlan.parse("embed:r0@op2=crash")
    with pytest.raises(PipelineFailure) as ei:
        pipe.serve(prompts, 12, group_size=4, injector=inj)
    e = ei.value
    assert (e.stage, e.replica) == ("embed", 0)
    for key in ("fifo_occupancy", "waiting", "schedule",
                "reorder_occupancy", "lost_ops"):
        assert key in e.diagnostics, f"diagnostic bundle missing {key}"


def test_lm_training_pipeline_fault_escalates_structured():
    """The training path has no failover hook by design: a replica fault
    surfaces as a structured PipelineFailure, never a hang."""
    import jax.numpy as jnp
    from repro.configs.base import ShapeCfg
    from repro.configs.tiny import CONFIG as tiny
    from repro.core import planner
    from repro.graphs import lm_graph
    from repro.runtime.pipeline import LMPipeline, selection_from_plan

    shape = ShapeCfg("pipe_fault", 16, 8, "train")
    plan = planner.plan(tiny, shape, chips=16, max_tp=4)
    stg, _ = lm_graph.build_stg(tiny, shape, max_tp=4)
    pipe = LMPipeline(tiny, stg, selection_from_plan(plan))
    rng = np.random.default_rng(0)
    mbs = [jnp.asarray(rng.integers(0, tiny.vocab, (2, 16)), jnp.int32)
           for _ in range(3)]
    # stage 0 (embed) round-robins microbatches over several replicas, so
    # r0 sees a single dispatch; target a single-replica block stage
    # where op-count 2 is actually reached
    target = pipe.stages[1].name
    inj = ReplicaFaultPlan(faults=[ReplicaFaultSpec(target, 0, at=2)])
    with pytest.raises(PipelineFailure) as ei:
        pipe.run(mbs, injector=inj)
    e = ei.value
    assert e.stage == target and e.replica == 0
    assert "no failover hook" in str(e)
    assert "schedule" in e.diagnostics


def test_stall_drives_health_controller_migration(chaos_setup):
    """Straggler loop end to end: a persistently stalled replica is
    flagged from live retire-latency histograms, its groups migrate to
    the healthy peer, repeated strikes produce replan advice — and the
    tokens stay bitwise-identical (migration copies caches)."""
    _, _, _, pipe, prompts, _ = chaos_setup
    ref = pipe.serve(prompts, 16, group_size=4)
    tr = Tracer()
    inj = ReplicaFaultPlan.parse("blocks00:r0@op1=stall:0.03x999")
    hc = HealthController(tracer=tr, threshold=1.5, min_samples=4,
                          check_every=8, replan_after=2)
    res = pipe.serve(prompts, 16, group_size=4, tracer=tr, injector=inj,
                     health=hc)
    assert res.tokens == ref.tokens
    assert hc.ticks > 0
    assert hc.reports, "stalled replica never flagged"
    assert all(r.stage == "blocks00" and r.replica == 0
               for r in hc.reports)
    assert hc.migrations >= 1, "no group migrated off the slow replica"
    assert hc.replan_advice is not None, "strikes never escalated"
    assert hc.replan_advice["blocks00"] > 1.5


def test_health_replan_advice_feeds_planner(chaos_setup):
    """The advice reaches the solver: pipeline stage names fan out to the
    graph nodes the stage owns (``graph_stage_map``), and the re-solve
    accepts the calibrated ratios."""
    tiny, stg, plan, pipe, prompts, _ = chaos_setup
    from repro.configs.base import ShapeCfg
    from repro.core import planner

    shape = ShapeCfg("chaos_test", 64, 16, "decode")
    owners = [n for n, s in pipe.graph_stage_map().items()
              if s == "blocks00"]
    assert owners, "blocks00 owns no graph nodes?"
    advice = {n: 3.0 for n in owners}        # graph-node keys: direct path
    new_plan, diff = planner.replan(tiny, shape, plan, new_chips=8,
                                    measured_ratio=advice)
    assert new_plan.stages and "chips" in diff


# ===========================================================================
# elastic rescale under live load: pause -> re-plan -> resume
# ===========================================================================
@pytest.fixture(scope="module")
def pause_setup():
    from repro.configs.base import ShapeCfg
    from repro.configs.tiny import CONFIG as tiny
    from repro.core import planner
    from repro.graphs import lm_graph

    shape = ShapeCfg("rescale_test", 64, 16, "decode")
    plan = planner.plan(tiny, shape, chips=8, max_tp=4)
    stg, _ = lm_graph.build_stg(tiny, shape, max_tp=4)
    pipe = DecodePipeline(tiny, stg, plan)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(2, tiny.vocab, rng.integers(4, 20)).tolist()
               for _ in range(8)]
    ref = pipe.serve(prompts, 12, group_size=4)
    return tiny, shape, plan, stg, pipe, prompts, ref


def _fresh_pause(pipe, prompts):
    """resume() runs the parked groups to completion *in place* (their
    caches are donated step by step), so every resuming test needs its
    own paused serve — a ResumeState is single-use by design."""
    paused = pipe.serve(prompts, 12, group_size=4, pause_after_tokens=3)
    assert paused.paused and paused.resume_state is not None
    assert paused.resume_state.live_groups()
    return paused.resume_state


@pytest.mark.parametrize("pps", [1, 2])
def test_pause_resume_token_parity_transfer_and_replay(pause_setup, pps):
    """pps=1: the successor's stage spans match the exporter's — caches
    *transfer* (device_put).  pps=2: spans moved — caches rebuild by
    deterministic *replay* from prompt + fed-token history.  Both must
    be bitwise what the uninterrupted serve produced."""
    tiny, _, plan, stg, pipe, prompts, ref = pause_setup
    state = _fresh_pause(pipe, prompts)
    succ = DecodePipeline(tiny, stg, plan, periods_per_stage=pps,
                          params=pipe._init_params)
    res = succ.resume(state)
    assert res.tokens == ref.tokens
    assert not res.paused


def test_rescale_serving_end_to_end(pause_setup):
    """The full live-rescale protocol: drain under admission pause,
    one solver call for a new chip budget, successor adopts the donated
    state, zero requests dropped."""
    from repro.runtime.elastic import rescale_serving

    tiny, shape, plan, stg, pipe, prompts, ref = pause_setup
    state = _fresh_pause(pipe, prompts)
    rs = rescale_serving(pipe, tiny, shape, plan, new_chips=6, stg=stg,
                         measured_ratio={"blocks00": 2.0})
    assert rs.plan.total_chips <= plan.total_chips
    assert "rescale" in rs.summary()
    res = rs.pipe.resume(state)
    assert res.tokens == ref.tokens


def test_resume_requires_live_groups(pause_setup):
    from repro.runtime.pipeline.decode import ResumeState

    tiny, _, plan, stg, pipe, *_ = pause_setup
    empty = ResumeState(groups=[], group_of=[], eos_id=1)
    with pytest.raises(ValueError, match="no live groups"):
        pipe.resume(empty)


# ===========================================================================
# injector re-arm + straggler warmup regressions (satellites)
# ===========================================================================
def test_failure_injector_rearms_across_incarnations():
    """Regression: ``fired`` is per-incarnation state.  Without reset(),
    a multi-restart drill could only kill a step once — a flaky node
    that dies after every restart was unrepresentable."""
    inj = FailureInjector(schedule={3: "crash"})
    with pytest.raises(SimulatedNodeFailure):
        inj.maybe_fail(3)
    inj.maybe_fail(3)                  # same incarnation: stays dead
    inj.reset()
    with pytest.raises(SimulatedNodeFailure):
        inj.maybe_fail(3)              # re-armed after the restart boundary
    assert [(i, s, k) for i, s, k in inj.log] == \
        [(0, 3, "crash"), (1, 3, "crash")]
    assert inj.incarnation == 1
    assert inj.new_incarnation == inj.reset     # documented alias


def test_replica_fault_plan_rearms_and_recounts():
    p = ReplicaFaultPlan.parse("w:r0@op2=crash")
    assert p.check("w", 0, 100) is None          # 1st dispatch: below trigger
    assert p.check("w", 0, 101) is not None      # 2nd: fires
    assert p.check("w", 0, 102) is None          # crash budget spent
    assert p.fired == 1
    p.new_incarnation()
    assert p.check("w", 0, 200) is None          # dispatch counters restarted
    assert p.check("w", 0, 201) is not None
    assert p.fired == 1                          # per-incarnation count
    assert [entry[0] for entry in p.log] == [0, 1]


def test_replica_fault_plan_parse_grammar():
    p = ReplicaFaultPlan.parse("blocks00:r1@tok64=crash",
                               "embed:r0@op8=stall:0.05x16")
    a, b = p.faults
    assert (a.stage, a.replica, a.at, a.unit, a.kind) == \
        ("blocks00", 1, 64, "tok", "crash")
    assert a.describe() == "blocks00:r1@tok64=crash"
    assert (b.unit, b.kind, b.repeat) == ("op", "stall:0.05", 16)
    assert b.stall_s == pytest.approx(0.05)
    for bad in ("nope", "s:r1@tok4=explode", "s:r1@foo4=crash",
                "s:rX@op4=crash", "s:r1@op4=stall:abc"):
        with pytest.raises(ValueError, match="bad fault spec"):
            ReplicaFaultPlan.parse(bad)


def test_straggler_monitor_warmup_resets_across_incarnations():
    """Regression: after new_incarnation() the next warmup_steps steps
    (restart recompiles — legitimately slow) must not be flagged against
    the pre-restart history."""
    mon = StragglerMonitor(window=16, threshold=2.0, warmup_steps=3)
    for i in range(6):
        mon.observe(i, 1.0)
    assert mon.observe(6, 10.0)                  # steady state: flagged
    mon.new_incarnation()
    for i in range(3):
        assert mon.observe(100 + i, 50.0) == [], \
            "recompile step flagged during post-restart warmup"
    assert mon.observed == 3


def test_straggler_monitor_emits_counter():
    reg = MetricsRegistry()
    mon = StragglerMonitor(warmup_steps=1, threshold=2.0, registry=reg)
    mon.observe(0, 1.0)
    mon.observe(1, 1.0)
    assert mon.observe(2, 10.0)
    assert reg.counter("straggler.flagged", host="0").value == 1.0
    mon.observe(3, 10.0)
    assert reg.counter("straggler.flagged", host="0").value == 2.0


def test_straggler_monitor_median_consistent_within_observe():
    """The healthy-filter and the flagging judgement share one pre-update
    median: a straggler must not shift the baseline it is judged by
    within the same observe call."""
    mon = StragglerMonitor(warmup_steps=1, threshold=2.0, window=8)
    mon.observe(0, 1.0)
    flagged = mon.observe(1, {0: 1.0, 1: 10.0})
    assert [(e.host, e.median) for e in flagged] == [(1, 1.0)]
    assert 10.0 not in mon._history              # straggler filtered out
    assert mon.median == 1.0
