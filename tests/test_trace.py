"""Observability layer (runtime/pipeline/trace + metrics + straggler).

Acceptance contract:
  * every dispatched op retires exactly once on its own track, and op
    spans on one replica never overlap (hypothesis, virtual clock);
  * watched-FIFO occupancy stays within [0, capacity] at every event;
  * both clock drivers emit *identical* per-track event sequences for
    the same `Program` (timestamps aside) — the one-event-model claim;
  * stall-based bottleneck attribution blames the stage the costs say
    is slow (credit waits blame the consumer, starves the producer);
  * the metrics registry, serving-SLO percentiles, straggler detector,
    deadlock diagnostics, and the measure-layer stall/starve columns
    behave as documented.
"""
import json

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.runtime.pipeline import (Engine, Fifo, MetricsRegistry, Op,
                                    Tracer, attribute_bottleneck,
                                    detect_replica_stragglers, fill_drain,
                                    one_f_one_b, registry_from_trace,
                                    run_event_loop, schedule_programs,
                                    serving_slo, simulate_schedule,
                                    stall_bottleneck)
from repro.runtime.pipeline.metrics import Histogram, percentile
from repro.runtime.pipeline.trace import (EV_DISPATCH, EV_POP, EV_PUSH,
                                          EV_RETIRE)


def _traced_virtual(sched, f_cost=1.0, b_cost=None):
    tr = Tracer()
    simulate_schedule(sched, f_cost=f_cost, b_cost=b_cost, tracer=tr)
    return tr


# ===========================================================================
# event-stream invariants (hypothesis)
# ===========================================================================
@settings(max_examples=20)
@given(p=st.integers(1, 5), mult=st.integers(1, 4), train=st.booleans())
def test_one_dispatch_retire_pair_per_op(p, mult, train):
    m = p * mult
    sched = one_f_one_b(p, m) if train else fill_drain(p, m)
    tr = _traced_virtual(sched)
    per_track: dict = {}
    for ev in tr.events:
        if ev.kind in (EV_DISPATCH, EV_RETIRE):
            per_track.setdefault(ev.track, []).append(ev)
    assert per_track, "no op events traced"
    n_ops = 0
    for track, evs in per_track.items():
        open_ops: set = set()
        for ev in evs:
            key = (ev.name, ev.seq, ev.chunk)
            if ev.kind == EV_DISPATCH:
                assert key not in open_ops, f"double dispatch {key} on {track}"
                open_ops.add(key)
            else:
                assert key in open_ops, f"retire without dispatch {key}"
                open_ops.remove(key)
                n_ops += 1
        assert not open_ops, f"{track}: ops never retired: {open_ops}"
    assert n_ops == len(sched.flatten())


@settings(max_examples=20)
@given(p=st.integers(1, 5), mult=st.integers(1, 4), train=st.booleans())
def test_replica_spans_never_overlap(p, mult, train):
    m = p * mult
    sched = one_f_one_b(p, m) if train else fill_drain(p, m)
    tr = _traced_virtual(sched, f_cost=2.0, b_cost=3.0)
    spans: dict = {}
    for ev in tr.events:
        if ev.kind == EV_RETIRE:
            spans.setdefault(ev.track, []).append((ev.t0, ev.t))
    for track, ss in spans.items():
        ss.sort()
        for (a0, a1), (b0, b1) in zip(ss, ss[1:]):
            assert a1 <= b0 + 1e-9, \
                f"{track}: span ({a0},{a1}) overlaps ({b0},{b1})"


@settings(max_examples=20)
@given(p=st.integers(2, 5), mult=st.integers(1, 4), cap=st.integers(1, 3))
def test_fifo_occupancy_within_bounds(p, mult, cap):
    sched = one_f_one_b(p, p * mult)
    programs, _ = schedule_programs(sched, capacity_blocks=cap)
    tr = Tracer()
    for i, f in enumerate(programs[0].acts):
        tr.watch_fifo(f, f"act{i}")
    for i, f in enumerate(programs[0].grds):
        tr.watch_fifo(f, f"grd{i}")
    run_event_loop({pr.name: pr for pr in programs}, tracer=tr)
    seen = 0
    for ev in tr.events:
        if ev.kind in (EV_PUSH, EV_POP):
            seen += 1
            capacity = tr.fifo_watch[ev.track].capacity
            assert 0 <= ev.value <= capacity, \
                f"{ev.track}: occupancy {ev.value} outside [0, {capacity}]"
    assert seen > 0


@settings(max_examples=15)
@given(p=st.integers(1, 4), mult=st.integers(1, 3), train=st.booleans())
def test_wall_and_virtual_drivers_emit_identical_sequences(p, mult, train):
    """The one-event-model contract: the same Program under the wall
    clock (serial engine) and the virtual clock produces the same
    per-(stage, replica) op sequence — only timestamps differ.  FIFO
    tracks are compared as per-kind counts, not interleavings: when two
    stages are simultaneously ready the drivers may pick them in
    different (both valid) orders, so the cross-stage interleave of
    pushes and pops on one edge is scheduler policy, not contract —
    what must match is every edge moving the same number of tokens."""
    m = p * mult
    sched = one_f_one_b(p, m) if train else fill_drain(p, m)

    def run_driver(wall: bool):
        programs, _ = schedule_programs(sched)
        tr = Tracer()
        for i, f in enumerate(programs[0].acts):
            tr.watch_fifo(f, f"act{i}")
        for i, f in enumerate(programs[0].grds):
            tr.watch_fifo(f, f"grd{i}")
        if wall:
            Engine(programs, overlap=False, tracer=tr).run()
        else:
            run_event_loop({pr.name: pr for pr in programs}, tracer=tr)
        assert all(pr.pending() == 0 for pr in programs)
        ops, fifo_counts = {}, {}
        for track, seq in tr.track_sequences().items():
            if track in tr.fifo_watch:
                counts = fifo_counts.setdefault(track, {})
                for ev in seq:
                    counts[ev[0]] = counts.get(ev[0], 0) + 1
            else:
                ops[track] = seq
        return ops, fifo_counts

    assert run_driver(wall=True) == run_driver(wall=False)


# ===========================================================================
# bottleneck attribution
# ===========================================================================
def test_attribution_blames_slow_stage():
    """Make stage1 3x slower than its peers: upstream credit-waits into
    it, downstream starves behind it — both blame stage1."""
    sched = fill_drain(3, 12)
    tr = _traced_virtual(
        sched, f_cost=lambda s, op: 3.0 if s == 1 else 1.0)
    assert stall_bottleneck(tr) == "stage1"
    ranked = attribute_bottleneck(tr)
    blamed = {e.stage: e.blamed for e in ranked}
    assert blamed["stage1"] > blamed.get("stage0", 0.0)
    assert blamed["stage1"] > blamed.get("stage2", 0.0)
    # the fast neighbours wait more than they cause: excess capacity
    by_stage = {e.stage: e for e in ranked}
    assert by_stage["stage0"].excess > 0
    assert by_stage["stage1"].excess < 0


def test_attribution_matches_analytic_bottleneck_on_stg():
    """The interpreter path: stall attribution and the analytic model
    must finger the same stage on a graph with one clear bottleneck.
    The nearly-idle sink downstream of `encode` collects almost as much
    raw *blame* (encode credit-blocks on the burst-rate encode->
    bitstream edge), which is exactly the misattribution the busy-capped
    `stall_bottleneck` verdict exists to reject."""
    from repro.core.fork_join import JPEG_CALIBRATED
    from repro.core.stg import Selection
    from repro.core.throughput import analyze
    from repro.graphs import jpeg
    from repro.runtime.pipeline import execute

    g = jpeg.build_stg()
    sel = Selection.fastest(g)
    tr = Tracer()
    execute(g, sel, {"camera": jpeg.random_blocks(64)},
            fj=JPEG_CALIBRATED, tracer=tr)
    assert stall_bottleneck(tr) == analyze(g, sel).bottleneck


# ===========================================================================
# metrics registry
# ===========================================================================
def test_percentile_nearest_rank():
    xs = [10.0, 20.0, 30.0, 40.0]
    assert percentile(xs, 50) == 20.0
    assert percentile(xs, 99) == 40.0
    assert percentile([7.0], 50) == 7.0
    assert percentile([], 50) != percentile([], 50)      # nan


def test_histogram_ring_keeps_exact_count_and_max():
    h = Histogram()
    for i in range(10000):
        h.observe(float(i))
    assert h.count == 10000
    assert h.vmax == 9999.0
    assert len(h.samples) <= 4096
    assert h.summary()["count"] == 10000


def test_registry_labels_and_type_guard():
    reg = MetricsRegistry()
    reg.counter("x", stage="a").inc(2)
    reg.counter("x", stage="a").inc(3)
    reg.counter("x", stage="b").inc(1)
    assert reg.counter("x", stage="a").value == 5.0
    assert len(reg.find("x")) == 2
    with pytest.raises(TypeError):
        reg.gauge("x", stage="a")


def test_registry_from_trace_builds_stage_metrics():
    sched = fill_drain(3, 9)
    tr = _traced_virtual(sched, f_cost=2.0)
    reg = registry_from_trace(tr, wall_s=60.0)
    busy = {tuple(sorted(l.items())): m.value
            for l, m in reg.find("pipeline.busy_s")}
    assert busy[(("replica", "0"), ("stage", "stage0"))] == pytest.approx(18.0)
    hists = reg.find("pipeline.retire_latency_us")
    assert {dict(l)["stage"] for l, _ in hists} == \
        {"stage0", "stage1", "stage2"}
    for _, h in hists:
        assert h.count == 9 and h.percentile(50) == pytest.approx(2e6)
    utils = {dict(l)["stage"]: m.value
             for l, m in reg.find("pipeline.utilization")}
    assert 0.0 < utils["stage1"] <= 1.0


def test_serving_slo_shape():
    slo = serving_slo([0.001, 0.002], [0.1, 0.2], [0.01, 0.02, 0.03])
    assert set(slo) == {f"{p}_p{q}_ms" for p in
                        ("queue_wait", "ttft", "token_gap")
                        for q in (50, 95, 99)}
    assert slo["ttft_p50_ms"] == pytest.approx(100.0)
    assert slo["token_gap_p99_ms"] == pytest.approx(30.0)


# ===========================================================================
# straggler detection
# ===========================================================================
def _reg_with_replicas(lat_by_rep: dict[int, float], n: int = 32):
    reg = MetricsRegistry()
    for rep, lat in lat_by_rep.items():
        h = reg.histogram("pipeline.retire_latency_us",
                          stage="blk", replica=str(rep))
        for _ in range(n):
            h.observe(lat)
    return reg


def test_straggler_flags_slow_replica():
    reg = _reg_with_replicas({0: 100.0, 1: 100.0, 2: 300.0})
    out = detect_replica_stragglers(reg)
    assert [(s.stage, s.replica) for s in out] == [("blk", 2)]
    assert out[0].ratio == pytest.approx(3.0)
    assert "blk/r2" in out[0].describe()


def test_straggler_quiet_on_healthy_and_sparse_data():
    assert detect_replica_stragglers(
        _reg_with_replicas({0: 100.0, 1: 110.0, 2: 95.0})) == []
    # below min_samples: no verdict, even with a huge skew
    assert detect_replica_stragglers(
        _reg_with_replicas({0: 100.0, 1: 900.0}, n=3)) == []
    # single replica: no peers to lag behind
    assert detect_replica_stragglers(_reg_with_replicas({0: 100.0})) == []


# ===========================================================================
# chrome-trace export
# ===========================================================================
def test_chrome_trace_has_tracks_and_counters():
    sched = one_f_one_b(3, 6)
    tr = _traced_virtual(sched)
    ct = tr.to_chrome_trace()
    json.dumps(ct)                                   # serializable
    evs = ct["traceEvents"]
    tracks = {e["args"]["name"] for e in evs
              if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"stage0/r0", "stage1/r0", "stage2/r0"} <= tracks
    slices = [e for e in evs if e["ph"] == "X"]
    counters = {e["name"] for e in evs if e["ph"] == "C"}
    assert len(slices) >= len(sched.flatten())
    assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in slices)
    assert "fifo act0" in counters and "fifo grd0" in counters


def test_save_roundtrip(tmp_path):
    tr = _traced_virtual(fill_drain(2, 4))
    path = tr.save(str(tmp_path / "trace.json"))
    with open(path) as f:
        assert json.load(f)["traceEvents"]


# ===========================================================================
# deadlock diagnostics
# ===========================================================================
def test_deadlock_report_attaches_fifo_and_trace_detail():
    fifo = Fifo(block=1, capacity_blocks=1)
    fifo.push([0], 0.0)                              # full from the start

    class Stuck:
        name = "writer"
        n_replicas = 1
        wait_reason = ("credit", fifo)

        def pending(self):
            return 1

        def peek(self):
            return Op(stage=0, kind="F", seq=0, rep=0)

        def ready(self, op, count_stall=False):
            return None

        def dispatch(self, op, driver):
            raise AssertionError

        def retire(self, *a):
            raise AssertionError

        def describe(self):
            return "writer: 0/1"

    tr = Tracer()
    tr.watch_fifo(fifo, "out", src="writer", dst="reader")
    eng = Engine([Stuck()], overlap=False, tracer=tr,
                 fifos={"out": fifo})
    with pytest.raises(RuntimeError, match="deadlock.*writer: 0/1") as ei:
        eng.run()
    msg = str(ei.value)
    assert "out=1/1" in msg                          # occupancy snapshot
    assert "credit" in msg and "on out" in msg       # who waits on what


def test_deadlock_message_first_line_unchanged_without_tracer():
    """The enriched report appends lines; the regex the engine tests pin
    (`deadlock.*stuck: 0/1`) keeps matching the first line untouched."""

    class Stuck:
        name = "stuck"
        n_replicas = 1

        def pending(self):
            return 1

        def peek(self):
            return Op(stage=0, kind="F", seq=0, rep=0)

        def ready(self, op, count_stall=False):
            return None

        def dispatch(self, op, driver):
            raise AssertionError

        def retire(self, *a):
            raise AssertionError

        def describe(self):
            return "stuck: 0/1"

    with pytest.raises(RuntimeError, match="deadlock.*stuck: 0/1"):
        Engine([Stuck()], overlap=False).run()


# ===========================================================================
# measure-layer surfacing
# ===========================================================================
def test_measure_summary_stall_columns_and_json_omission():
    from repro.core.fork_join import JPEG_CALIBRATED
    from repro.core.stg import Selection
    from repro.graphs import jpeg
    from repro.runtime.pipeline import compare, execute

    g = jpeg.build_stg()
    sel = Selection.fastest(g)
    blocks = jpeg.random_blocks(64)
    tr = Tracer()
    rep = compare(g, sel, execute(g, sel, {"camera": blocks},
                                  fj=JPEG_CALIBRATED, tracer=tr))
    assert "stall" in rep.summary() and "starve" in rep.summary()
    assert "host -" in rep.summary()                 # virtual clock: n/a
    stages = json.loads(rep.to_json())["stages"]
    assert all("host_us" not in s for s in stages.values())
    assert any("stall" in s for s in stages.values())

    rep2 = compare(g, sel, execute(g, sel, {"camera": blocks},
                                   fj=JPEG_CALIBRATED))
    stages2 = json.loads(rep2.to_json())["stages"]
    assert all("stall" not in s and "starve" not in s
               for s in stages2.values())            # untraced: omitted
    assert "None" not in rep2.summary()


def test_overhead_untraced_path_identical_results():
    """Tracing off must not change execution: same outputs, same cycle
    count, no tracer attribute left on any fifo."""
    from repro.core.fork_join import JPEG_CALIBRATED
    from repro.core.stg import Selection
    from repro.graphs import jpeg
    from repro.runtime.pipeline import execute

    g = jpeg.build_stg()
    sel = Selection.fastest(g)
    blocks = jpeg.random_blocks(64)
    tr = Tracer()
    traced = execute(g, sel, {"camera": blocks}, fj=JPEG_CALIBRATED,
                     tracer=tr)
    plain = execute(g, sel, {"camera": blocks}, fj=JPEG_CALIBRATED)
    assert traced.outputs == plain.outputs
    assert traced.cycles == plain.cycles
    assert all(f.tracer is None for f in plain.channels.fifos.values())
    assert not plain.wait_cycles and traced.wait_cycles
