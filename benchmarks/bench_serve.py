"""Decode serving A/B: pipelined STG backend vs the single-device loop.

Serves the same request queue twice through `runtime/server.LMServer` —
once with the single-device prefill/decode loop, once with the decode
pipeline (`runtime/pipeline/decode.DecodePipeline`: planner's decode-shape
plan placed on the local pool, request groups streamed concurrently,
per-stage KV-cache slices resident, token feedback stream) — and reports
decode tokens/s plus p50/p95 per-token latency for both, as a table and
as JSON (the CI artifact consumed by regression tooling).

Both backends generate token-identical completions (asserted), so the A/B
is apples-to-apples work.

A third arm (backend ``pipelined-fused``) reruns the pipelined serve under
the planner-selected fusion plan (`core.restructure` stage combining: the
unfused run's measured ``per_stage_host_us`` folded into the virtual-clock
score, one AOT program per combined stage).  Token parity with the
single-device reference and ``late == 0`` compile stats are asserted, the
re-scored plan from the fused run's own measurements must be a fixed
point, and ``--smoke`` gates fused > unfused decode tokens/s
(interleaved best-of-N, same noise discipline as the tracing gate).

``--smoke`` serves a reduced request queue (same config, fewer slots) —
the PR-CI perf gate: its rows (workload ``serve/tiny-smoke``) are diffed
against the committed ``benchmarks/baseline-smoke/`` by
`tools/bench_compare.py`, failing the job on a decode-tokens/s
regression.  Rows also carry per-stage host dispatch overhead
(``per_stage_host_us``, the engine's dispatch-wall-minus-device-compute
accounting) so host-side regressions are visible separately from stage
inverse throughput.

Observability surface: the pipelined row includes serving SLO
percentiles (queue wait / TTFT / inter-token gap p50/p95/p99, from
`ServeRunResult.slo()`), per-stage stall/starve milliseconds and the
stall-attributed bottleneck from a traced replay, and a Chrome-trace /
Perfetto export written next to the JSON (``*_trace.json``; open at
https://ui.perfetto.dev).  ``--smoke`` additionally gates the tracing
overhead: best-of-N traced decode tokens/s must stay within 3% of
best-of-N untraced, and the stall bottleneck must land in the analytic
ranking's top tier.

Roofline accounting: host memory bandwidth is *measured* once per run
(`analysis.roofline.measure_host_bandwidth`), each pipeline stage's
decode step gets a bytes-moved bound
(`analysis.roofline.decode_stage_bytes`: params streamed once + live KV
prefix read + slot written), and the rows report
``per_stage_fraction_of_roofline`` — the bytes/bw floor over the
fastest observed decode service time per stage (min over that stage's
``op_trace`` decode spans).  1.0 means the step runs at the bandwidth
bound; fractions above 1 are expected at smoke scale, where the
working set sits in CPU caches above DRAM.  The lone embed stage
reports but never gates (it moves ~KBs per step — dispatch-bound by
construction).  ``--smoke`` gates every other stage at
``ROOFLINE_GATE_FRACTION``.

A fourth arm (backend ``pipelined-refdecode``) reruns the pipelined
serve with ``impl="ref"`` — the historical op-by-op decode body the
fused kernels replaced — asserting token parity (the kernel swap may
not change a single sampled token) and recording its tokens/s next to
the fused default's.  The kernel win itself is gated on the isolated
single-device decode step (donated jit, interleaved min-time bursts,
best-of-N with early exit): ``--smoke`` fails unless the fused step
beats the ref step (``kernel_step_speedup > 1``).

Chaos drill (``--inject 'decode:r1@tok64=crash'``): serves a deep decode
window twice through one extra pipeline — fault-free, then with a
`runtime.failures.ReplicaFaultPlan` killing the named (stage, replica)
mid-stream — and asserts the failover engine recovered with **bitwise
token parity** (``tokens_lost == 0``).  The pseudo-stage ``decode``
resolves to the first multi-replica block stage (forcing a 2-replica
layout when the plan placed none, so a crash always has survivors).  The
row (backend ``pipelined-chaos``) reports ``recovery_ms`` and
``tokens_lost`` for `tools/bench_compare.py` (warn-only).

    PYTHONPATH=src python -m benchmarks.bench_serve [--json out.json]
                                                    [--smoke]
                                                    [--inject SPEC]
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

# --smoke floor for per-stage fraction_of_roofline (decode steps, every
# stage but the lone embed).  Deliberately lenient: smoke-sized stages
# are dispatch-dominated, so the gate catches "the kernel path fell off
# a cliff" (an accidental ref fallback, a per-step recompile), not
# "the step left the roofline's neighbourhood" — block stages and head
# measure ~0.15-0.20 on the reference dev host (see the committed
# baseline-smoke rows), an order of magnitude above this floor.
ROOFLINE_GATE_FRACTION = 0.02


def _check_trace(tracer, pipe) -> None:
    """The export contract: at least one op track per (stage, replica)
    that retired work, a waits track where stalls happened, and a counter
    track per watched fifo."""
    ct = tracer.to_chrome_trace()
    tracks = {e["args"]["name"] for e in ct["traceEvents"]
              if e["ph"] == "M" and e["name"] == "thread_name"}
    for track in tracer.n_retire:
        assert track in tracks, f"no track for {track}"
    for stage in pipe.stage_names:
        assert any(t.startswith(stage + "/r") for t in tracks), \
            f"stage {stage} has no replica track"
    counters = {e["name"] for e in ct["traceEvents"] if e["ph"] == "C"}
    assert counters >= {f"fifo {lbl}" for lbl in tracer.fifo_watch}, \
        f"missing fifo counter tracks: {counters}"
    json.dumps(ct)


def _percentiles(samples_s: list[float]) -> tuple[float, float]:
    if not samples_s:
        return float("nan"), float("nan")
    arr = np.sort(np.asarray(samples_s))
    return (float(np.percentile(arr, 50)) * 1e3,
            float(np.percentile(arr, 95)) * 1e3)


def _stage_rooflines(cfg, pipe, res, batch: int, bw: float) -> dict:
    """Per-stage ``fraction_of_roofline`` for the decode step.

    Bytes: `roofline.decode_stage_bytes` at the most conservative live
    cache length any decode step saw (the smallest group's prompt
    bucket — a guaranteed lower bound on what every step read), so the
    fraction is a true lower bound on the achieved fraction.  Time: the
    FASTEST observed decode service time per stage (min over its
    ``op_trace`` decode spans — the steady-state step, free of warm-up
    and scheduling hiccups, matching the conservative byte count)."""
    from repro.analysis import roofline

    best_s: dict[str, float] = {}
    for stage, kind, _seq, _rep, t_d, t_done in res.op_trace:
        if kind == "D" and t_done > t_d:
            best_s[stage] = min(t_done - t_d,
                                best_s.get(stage, float("inf")))
    cache_len = min(g.bucket for g in res.groups)
    out = {}
    for desc in pipe.stage_descs:
        if desc.name not in best_s:
            continue
        nbytes = roofline.decode_stage_bytes(
            cfg, batch=batch, cache_len=cache_len, span=desc.span,
            has_embed=desc.has_embed, has_head=desc.has_head)
        out[desc.name] = roofline.fraction_of_roofline(
            nbytes, best_s[desc.name], bw)
    return out


def _gated_stages(pipe, fractions: dict) -> dict:
    """The stages the roofline gate applies to: everything but a lone
    embed (a per-token row gather moves ~KBs — dispatch-bound by
    construction, so its fraction is reported but never gated)."""
    return {d.name: fractions[d.name] for d in pipe.stage_descs
            if d.name in fractions and (d.span is not None or d.has_head)}


def _kernel_step_ab(cfg, batch: int) -> dict:
    """Isolated decode-step A/B: the historical op-by-op ``ref`` body vs
    the fused decode-kernel path, timed as the donated single-device
    step jit (`lm.decode_step`, cache donated — the serving hot path
    with sampling and queue bookkeeping stripped away).

    Interleaved min-time bursts: the min over a 40-step burst is the
    stable statistic at smoke scale (tokens/s wanders +-10% on a shared
    host while the burst-min moves well under 1%), rounds alternate
    fused/ref so host drift hits both arms symmetrically, and the loop
    exits early once fused is ahead (symmetric — every completed round
    times both arms equally).  Decoding continues past the ring
    capacity, so every timed step runs at the full live cache — steady
    work."""
    import functools

    import jax
    import jax.numpy as jnp

    from repro.models import lm

    bucket, cap = 24, 72
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(2, cfg.vocab, (batch, bucket)))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    arms = {}
    for impl in ("ref", "fused"):
        step = jax.jit(functools.partial(lm.decode_step, cfg, impl=impl),
                       donate_argnums=(1,))
        _, cache = lm.prefill(cfg, params, {"tokens": toks}, capacity=cap)
        cur = toks[:, -1:]
        logits, cache = step(params, cache, cur)        # compile + warm
        jax.block_until_ready(logits)
        arms[impl] = [step, cache, cur]
    best = {"ref": float("inf"), "fused": float("inf")}
    for rnd in range(5):
        for impl in ("fused", "ref"):
            step, cache, cur = arms[impl]
            for _ in range(40):
                t0 = time.perf_counter()
                logits, cache = step(params, cache, cur)
                jax.block_until_ready(logits)
                best[impl] = min(best[impl], time.perf_counter() - t0)
            arms[impl][1] = cache
        if rnd >= 1 and best["fused"] < best["ref"]:
            break
    return best


def _chaos_arm(cfg, stg, plan, reqs, group: int, inject: str,
               workload: str) -> dict:
    """Serve a deep decode window fault-free, replay it with the injected
    replica fault, and prove failover kept token parity."""
    from repro.runtime.failures import ReplicaFaultPlan
    from repro.runtime.pipeline import DecodePipeline, as_selection

    stage_alias = inject.split(":r", 1)[0]
    sel = as_selection(plan)
    probe = DecodePipeline(cfg, stg, sel, warmup=False)
    owners = {}                      # stage name -> graph nodes it executes
    for node, stage in probe.graph_stage_map().items():
        owners.setdefault(stage, []).append(node)
    multi = [s for s in probe.stage_names
             if s.startswith("blocks")
             and len(probe.stage_devices[probe.stage_names.index(s)]) >= 2]
    if stage_alias == "decode":      # drill shorthand: any failover-capable
        target = multi[0] if multi \
            else next(s for s in probe.stage_names if s.startswith("blocks"))
    else:
        target = stage_alias
    if len(probe.stage_devices[probe.stage_names.index(target)]) < 2:
        # single-replica target would escalate, not fail over: force two
        # replicas on every node the stage owns (owners must agree)
        for node in owners[target]:
            sel.set(node, sel.choices[node][0], 2)
    spec = target + inject[len(stage_alias):]

    pipe = DecodePipeline(cfg, stg, sel)
    prompts = [r.prompt for r in reqs]
    deep = 48                        # enough decode traffic for tok-triggers
    pipe.serve(prompts, deep, group_size=group)         # warm
    ref = pipe.serve(prompts, deep, group_size=group)   # fault-free reference
    injector = ReplicaFaultPlan.parse(spec)
    res = pipe.serve(prompts, deep, group_size=group, injector=injector)
    assert injector.fired > 0, \
        f"chaos drill vacuous: {spec!r} never fired ({res.decode_tokens} toks)"
    assert res.failovers or injector.fired, "no failover recorded"
    tokens_lost = sum(max(0, len(a) - len(b))
                      for a, b in zip(ref.tokens, res.tokens))
    assert res.tokens == ref.tokens, \
        f"failover lost token parity ({tokens_lost} tokens lost)"
    return {
        "workload": workload,
        "backend": "pipelined-chaos",
        "inject": spec,
        "fired": injector.fired,
        "failovers": res.failovers,
        "recovery_ms": 1e3 * sum(f["recovery_s"] for f in res.failovers),
        "tokens_lost": tokens_lost,
        "decode_tok_per_s": res.decode_tokens_per_s(),
        "decode_tokens": res.decode_tokens,
        "wall_s": res.wall_s,
        "note": "fault injected mid-stream; parity asserted against a "
                "fault-free serve of the same pipeline",
    }


def run(verbose: bool = True, json_path: str | None = None,
        smoke: bool = False, inject: str | None = None) -> list[dict]:
    from repro.configs.base import ShapeCfg
    from repro.configs.tiny import CONFIG as tiny
    from repro.core import planner
    from repro.core.throughput import analyze
    from repro.graphs import lm_graph
    from repro.runtime.pipeline import (DecodePipeline, Tracer,
                                        selection_from_plan,
                                        stall_bottleneck)
    from repro.runtime.server import LMServer, Request

    from repro.analysis import roofline

    shape = ShapeCfg("bench_serve", 64, 16, "decode")
    plan = planner.plan(tiny, shape, chips=8, max_tp=4)
    stg, _ = lm_graph.build_stg(tiny, shape, max_tp=4)

    # one bandwidth measurement anchors every fraction_of_roofline below:
    # same host, same run — the denominator the datasheet can't provide
    bw = roofline.measure_host_bandwidth()

    rng = np.random.default_rng(0)
    n_req, max_new = (8, 12) if smoke else (16, 16)
    workload = "serve/tiny-smoke" if smoke else "serve/tiny"
    reqs = [Request(uid=i,
                    prompt=rng.integers(2, tiny.vocab,
                                        rng.integers(4, 24)).tolist(),
                    max_new=max_new)
            for i in range(n_req)]
    group = 4

    rows = []

    # -- single-device baseline ---------------------------------------------
    srv = LMServer(tiny, max_batch=group)
    srv.serve(reqs)                       # warm every bucket's jit cache
    srv.stats.__init__()
    t0 = time.perf_counter()
    ref_out = srv.serve(reqs)
    single_wall = time.perf_counter() - t0
    s = srv.stats
    # real per-step timestamps: the decode loop host-syncs every step, so
    # each recorded gap is one true step time and p50/p95 are honest
    # percentiles over steps, not a per-request mean smeared flat
    p50, p95 = _percentiles(s.decode_step_s)
    # whole-model roofline: one decode step moves every layer's params +
    # live cache + the head matrix; the conservative cache_len (shortest
    # prompt) keeps the fraction a lower bound like the per-stage ones
    single_bytes = roofline.decode_stage_bytes(
        tiny, batch=group, cache_len=min(len(r.prompt) for r in reqs),
        span=(0, tiny.n_periods), has_embed=True, has_head=True)
    rows.append({
        "workload": workload,
        "backend": "single-device",
        "decode_tok_per_s": s.decode_tokens / s.decode_s if s.decode_s else 0,
        "prefill_tok_per_s": (s.prefill_tokens / s.prefill_s
                              if s.prefill_s else 0),
        "p50_token_ms": p50,
        "p95_token_ms": p95,
        "decode_tokens": s.decode_tokens,
        "decode_steps": len(s.decode_step_s),
        "wall_s": single_wall,
        "host_bw_gbs": bw / 1e9,
        "fraction_of_roofline": roofline.fraction_of_roofline(
            single_bytes, min(s.decode_step_s), bw),
    })

    # -- pipelined ----------------------------------------------------------
    # build-time warmup (AOT precompile) replaces the old throwaway serve:
    # the first serve call of each shape already runs compile-free
    pipe = DecodePipeline(tiny, stg, plan)
    pipe.serve([r.prompt for r in reqs], [r.max_new for r in reqs],
               group_size=group)          # steady-state measurement parity
    run_res = pipe.serve([r.prompt for r in reqs],
                         [r.max_new for r in reqs], group_size=group)
    assert pipe.compile_stats.late == 0, \
        f"compiles landed inside the timed serve: {pipe.compile_stats.summary()}"
    for c, toks in zip(ref_out, run_res.tokens):
        assert c.tokens == toks, "pipelined backend diverged from reference"
    # -- traced replay: observability surface -------------------------------
    # fresh tracer (aggregates accumulate across runs sharing one), same
    # workload — stall/starve attribution and the Perfetto export come
    # from this arm so the reported rates above stay trace-free
    tracer = Tracer()
    traced_res = pipe.serve([r.prompt for r in reqs],
                            [r.max_new for r in reqs], group_size=group,
                            tracer=tracer)
    assert traced_res.tokens == run_res.tokens, \
        "tracing changed the generated tokens"
    _check_trace(tracer, pipe)
    # every stage gets a row — including the source stage (embed), whose
    # queue-empty idle the engine now attributes via `idle_reason()`;
    # stages that never waited report an explicit 0.0
    stall_ms = {s: 1e3 * traced_res.stage_wait_s.get(s, {}).get("credit", 0.0)
                for s in pipe.stage_names}
    starve_ms = {s: 1e3 * (traced_res.stage_wait_s.get(s, {}).get("starve", 0.0)
                           + traced_res.stage_wait_s.get(s, {}).get("reorder", 0.0))
                 for s in pipe.stage_names}
    measured_btl = stall_bottleneck(tracer)
    stage_frac = _stage_rooflines(tiny, pipe, run_res, group, bw)
    gated_frac = _gated_stages(pipe, stage_frac)

    trace_path = None
    if json_path:
        trace_path = (json_path[:-5] if json_path.endswith(".json")
                      else json_path) + "_trace.json"
        tracer.save(trace_path)

    if smoke:
        # roofline gate: every decode stage but the lone embed must sit
        # above the stated fraction of its bytes/bw floor — a collapse
        # here means the step stopped being the kernel path (accidental
        # ref fallback, per-step recompile), not host noise
        assert gated_frac and min(gated_frac.values()) >= \
            ROOFLINE_GATE_FRACTION, \
            (f"decode step fell below {ROOFLINE_GATE_FRACTION:.0%} of its "
             f"memory-bandwidth roofline: "
             f"{ {k: round(v, 4) for k, v in gated_frac.items()} } "
             f"(host bw {bw / 1e9:.1f} GB/s)")
        # the stall ranking must finger the analytic ranking's top tier
        # (the tiny plan's block stages tie at the analytic top, so any
        # of them is a correct answer — embed/head would not be)
        a = analyze(stg, selection_from_plan(plan))
        graph_of = {v: k for k, v in pipe.graph_stage_map().items()}
        top = {n for n, v in a.node_iter_time.items()
               if v >= 0.99 * max(a.node_iter_time.values())}
        assert graph_of.get(measured_btl) in top, \
            (f"stall bottleneck {measured_btl} not in analytic top tier "
             f"{sorted(top)}")
        # tracing overhead gate.  Single-serve tokens/s swings +-10% on a
        # shared host, so the estimator is built to find the noise
        # ceiling of each arm rather than trust one sample: a longer
        # decode window than the A/B rows (more tokens per sample),
        # interleaved traced/plain pairs (shared host drift), best-of-N
        # per arm, and one best-of-5 escalation before failing.
        prompts = [r.prompt for r in reqs]
        deep = 48
        pipe.serve(prompts, deep, group_size=group)     # warm the shape
        plain_best = traced_best = 0.0
        for i in range(5):
            traced_best = max(traced_best, pipe.serve(
                prompts, deep, group_size=group,
                tracer=Tracer()).decode_tokens_per_s())
            plain_best = max(plain_best, pipe.serve(
                prompts, deep, group_size=group).decode_tokens_per_s())
            if i >= 2 and 1.0 - traced_best / plain_best < 0.03:
                break
        overhead = 1.0 - traced_best / plain_best
        assert overhead < 0.03, \
            (f"tracing overhead {overhead:.1%} >= 3% "
             f"({traced_best:.1f} vs {plain_best:.1f} tok/s)")
    assert pipe.compile_stats.late == 0, \
        f"compiles landed inside a timed serve: {pipe.compile_stats.summary()}"

    p50, p95 = _percentiles(run_res.token_latencies_s())
    rows.append({
        "workload": workload,
        "backend": "pipelined",
        "decode_tok_per_s": run_res.decode_tokens_per_s(),
        # window until the LAST prefill lands (overlaps decode: the rate
        # is a lower bound under pipelining, never inflated)
        "prefill_tok_per_s": (run_res.prefill_tokens
                              / max(max(g.t_prefill_done
                                        for g in run_res.groups), 1e-9)),
        "p50_token_ms": p50,
        "p95_token_ms": p95,
        "decode_tokens": run_res.decode_tokens,
        "wall_s": run_res.wall_s,
        "per_stage_host_us": {n: run_res.stage_host_us(n)
                              for n in pipe.stage_names},
        "per_stage_fraction_of_roofline": stage_frac,
        "fraction_of_roofline": (min(gated_frac.values())
                                 if gated_frac else float("nan")),
        "host_bw_gbs": bw / 1e9,
        "per_stage_stall_ms": stall_ms,
        "per_stage_starve_ms": starve_ms,
        "stall_bottleneck": measured_btl,
        "slo": run_res.slo(),
        "trace_json": trace_path,
        "compile_stats": pipe.compile_stats.summary(),
        "groups": len(run_res.groups),
        "planned_stage_replicas": {sp.name: sp.replicas
                                   for sp in plan.stages},
        "oversubscription": run_res.placement.oversubscription,
        "note": "single-host pool: oversubscribed stages time-share one "
                "device, so the A/B measures scheduling overhead there and "
                "real pipelining on multi-device pools",
    })

    for k, v in rows[-1]["slo"].items():
        rows[-1][k] = v                    # flat copies for bench_compare

    # -- fused pipelined: planner-selected stage combining ------------------
    # score candidate fusion plans on the virtual clock with the UNFUSED
    # run's measured per-stage dispatch cost folded in, execute the
    # winner (one AOT program per combined stage — one dispatch, one fifo
    # hop deleted per fused boundary), and prove the row is the same
    # serve: bitwise token parity against the single-device reference
    host_us = {n: run_res.stage_host_us(n) for n in pipe.stage_names}
    host_us = {k: v for k, v in host_us.items() if np.isfinite(v)}
    fusion = planner.plan_fusion(tiny, shape, plan, host_us=host_us)
    fpipe = DecodePipeline(tiny, stg, plan, fusion_plan=fusion.groups)
    fpipe.serve([r.prompt for r in reqs], [r.max_new for r in reqs],
                group_size=group)          # steady-state parity with above
    fused_res = fpipe.serve([r.prompt for r in reqs],
                            [r.max_new for r in reqs], group_size=group)
    assert fpipe.compile_stats.late == 0, \
        f"compiles landed inside the fused serve: {fpipe.compile_stats.summary()}"
    for c, toks in zip(ref_out, fused_res.tokens):
        assert c.tokens == toks, "fused pipeline diverged from reference"
    # fixed point: re-scoring with the FUSED run's measured dispatch cost
    # must keep the same plan (members absent from the fused measurement
    # inherit their group's dispatch cost)
    fused_host = {n: fused_res.stage_host_us(n) for n in fpipe.stage_names}
    fused_host = {k: v for k, v in fused_host.items() if np.isfinite(v)}
    confirm = planner.plan_fusion(tiny, shape, plan, host_us=fused_host)
    unfused_rate = run_res.decode_tokens_per_s()
    fused_rate = fused_res.decode_tokens_per_s()
    if smoke:
        # perf gate with the same noise discipline as the tracing gate:
        # interleaved best-of-N pairs, early exit once fused wins
        prompts = [r.prompt for r in reqs]
        deep = 48
        pipe.serve(prompts, deep, group_size=group)       # warm shapes
        fpipe.serve(prompts, deep, group_size=group)
        fused_best = plain_best = 0.0
        for i in range(5):
            fused_best = max(fused_best, fpipe.serve(
                prompts, deep, group_size=group).decode_tokens_per_s())
            plain_best = max(plain_best, pipe.serve(
                prompts, deep, group_size=group).decode_tokens_per_s())
            if i >= 2 and fused_best > plain_best:
                break
        assert fused_best > plain_best, \
            (f"fusion did not win: {fused_best:.1f} fused vs "
             f"{plain_best:.1f} unfused tok/s")
        fused_rate, unfused_rate = fused_best, plain_best
    p50, p95 = _percentiles(fused_res.token_latencies_s())
    fused_frac = _stage_rooflines(tiny, fpipe, fused_res, group, bw)
    fused_gated = _gated_stages(fpipe, fused_frac)
    rows.append({
        "workload": workload,
        "backend": "pipelined-fused",
        "decode_tok_per_s": fused_rate,
        "prefill_tok_per_s": (fused_res.prefill_tokens
                              / max(max(g.t_prefill_done
                                        for g in fused_res.groups), 1e-9)),
        "p50_token_ms": p50,
        "p95_token_ms": p95,
        "decode_tokens": fused_res.decode_tokens,
        "wall_s": fused_res.wall_s,
        "fused_groups": [list(g) for g in fusion.groups],
        "fusion_period_us": fusion.period_us,
        "fusion_fixed_point": confirm.groups == fusion.groups,
        "speedup_vs_unfused": (fused_rate / unfused_rate
                               if unfused_rate else float("nan")),
        "per_stage_host_us": {n: fused_res.stage_host_us(n)
                              for n in fpipe.stage_names},
        "per_stage_fraction_of_roofline": fused_frac,
        "fraction_of_roofline": (min(fused_gated.values())
                                 if fused_gated else float("nan")),
        "slo": fused_res.slo(),
        "compile_stats": fpipe.compile_stats.summary(),
        "planned_stage_replicas": {sp.name: sp.replicas
                                   for sp in plan.stages},
        "note": "same plan as `pipelined` with planner-selected stage "
                "combining; token parity asserted against the "
                "single-device reference",
    })
    for k, v in rows[-1]["slo"].items():
        rows[-1][k] = v

    # -- ref-decode A/B: the decode-kernel swap, measured in one run --------
    # same plan, same requests, impl="ref" — the historical op-by-op
    # decode body the fused kernels replaced.  Token parity proves the
    # kernel swap changed no sampled token; the rate sits next to the
    # fused default's in the JSON so the serve-level delta is on record.
    rpipe = DecodePipeline(tiny, stg, plan, impl="ref")
    rpipe.serve([r.prompt for r in reqs], [r.max_new for r in reqs],
                group_size=group)          # steady-state parity with above
    rdec_res = rpipe.serve([r.prompt for r in reqs],
                           [r.max_new for r in reqs], group_size=group)
    assert rpipe.compile_stats.late == 0, \
        f"compiles landed inside the ref serve: {rpipe.compile_stats.summary()}"
    for c, toks in zip(ref_out, rdec_res.tokens):
        assert c.tokens == toks, "ref-impl pipeline diverged from reference"
    # the kernel win itself, gated where it is measurable: the isolated
    # donated decode step (serve-level rates at smoke scale are dispatch
    # noise; the step-level burst-min is stable to well under 1%)
    step_best = _kernel_step_ab(tiny, group)
    if smoke:
        assert step_best["fused"] < step_best["ref"], \
            (f"fused decode step did not beat the ref body: "
             f"{step_best['fused'] * 1e3:.3f} ms fused vs "
             f"{step_best['ref'] * 1e3:.3f} ms ref")
    rows.append({
        "workload": workload,
        "backend": "pipelined-refdecode",
        "decode_tok_per_s": rdec_res.decode_tokens_per_s(),
        "decode_tokens": rdec_res.decode_tokens,
        "wall_s": rdec_res.wall_s,
        "decode_step_ms_ref": step_best["ref"] * 1e3,
        "decode_step_ms_fused": step_best["fused"] * 1e3,
        "kernel_step_speedup": step_best["ref"] / step_best["fused"],
        "note": "impl='ref' rerun of the pipelined arm (token parity "
                "asserted); decode_step_ms_* is the isolated donated "
                "single-device step, interleaved burst-min best-of-N",
    })

    # -- chaos drill --------------------------------------------------------
    if inject:
        rows.append(_chaos_arm(tiny, stg, plan, reqs, group, inject,
                               workload))
        if verbose:
            r = rows[-1]
            print(f"chaos: {r['inject']} fired x{r['fired']}, "
                  f"recovery {r['recovery_ms']:.1f} ms, "
                  f"tokens lost {r['tokens_lost']}")

    if verbose:
        for r in rows:
            line = (f"{r['workload']:14s} {r['backend']:14s} "
                    f"decode {r['decode_tok_per_s']:8.1f} tok/s | ")
            if "p50_token_ms" in r:
                line += (f"token p50 {r['p50_token_ms']:6.1f} ms "
                         f"p95 {r['p95_token_ms']:6.1f} ms | ")
            print(line + f"wall {r['wall_s']:.2f}s")
        for r in rows:
            if r.get("stall_bottleneck"):
                print(f"stall bottleneck: {r['stall_bottleneck']} | "
                      f"ttft p95 {r['ttft_p95_ms']:.1f} ms | "
                      f"token gap p99 {r['token_gap_p99_ms']:.1f} ms")
            if r.get("per_stage_fraction_of_roofline"):
                print(f"roofline ({r['backend']}, host bw "
                      f"{bw / 1e9:.1f} GB/s): "
                      + "  ".join(f"{k} {v:.3f}" for k, v in
                                  r["per_stage_fraction_of_roofline"].items()))
            if "kernel_step_speedup" in r:
                print(f"decode step: ref {r['decode_step_ms_ref']:.3f} ms, "
                      f"fused {r['decode_step_ms_fused']:.3f} ms "
                      f"(x{r['kernel_step_speedup']:.3f})")
        print(json.dumps(rows, indent=2))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=2)
        if verbose:
            print(f"wrote {json_path}")
    return rows


if __name__ == "__main__":
    path = spec = None
    usage = "usage: bench_serve [--json PATH] [--smoke] [--inject SPEC]"
    if "--json" in sys.argv:
        i = sys.argv.index("--json") + 1
        if i >= len(sys.argv):
            sys.exit(usage)
        path = sys.argv[i]
    if "--inject" in sys.argv:
        i = sys.argv.index("--inject") + 1
        if i >= len(sys.argv):
            sys.exit(usage)
        spec = sys.argv[i]
    run(verbose=True, json_path=path, smoke="--smoke" in sys.argv,
        inject=spec)
