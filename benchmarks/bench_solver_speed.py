"""Paper §II.B.1 claim: "ILP is usually slower than our heuristic".

Times both engines on the JPEG graph and on LM task graphs of increasing
size (qwen 36 stages -> deepseek 62 -> jamba 72).  On small graphs with
precomputable choice grids HiGHS is fast; the claim re-emerges as graphs
grow and the MILP grid blows up (and when no MILP backend exists, the
exact branch-and-bound fallback is exponential).
"""
from __future__ import annotations

import time

from repro.configs import SHAPES, get_config
from repro.core import heuristic, ilp, planner
from repro.core.fork_join import JPEG_CALIBRATED
from repro.graphs.jpeg import build_stg


def rows():
    out = []
    g = build_stg()
    for v in (1, 4):
        ri = ilp.min_area(g, v, JPEG_CALIBRATED)
        rh = heuristic.min_area(g, v, JPEG_CALIBRATED)
        out.append({"problem": f"jpeg v={v}", "ilp_ms": ri.solve_seconds * 1e3,
                    "heur_ms": rh.solve_seconds * 1e3,
                    "ilp_area": ri.total_area, "heur_area": rh.total_area})
    for arch in ("qwen2.5-3b", "deepseek-coder-33b", "jamba-1.5-large-398b"):
        cfg = get_config(arch)
        budget = 512 if "jamba" in arch else 256
        for eng in ("ilp", "heuristic"):
            t0 = time.perf_counter()
            p = planner.plan(cfg, SHAPES["train_4k"], chips=budget, engine=eng)
            dt = time.perf_counter() - t0
            out.append({"problem": f"{arch} (budget {budget})", "engine": eng,
                        "wall_ms": dt * 1e3, "chips": p.total_chips,
                        "tok_per_s": p.tokens_per_s})
    return out


def run(verbose=True):
    rs = rows()
    if verbose:
        print("# Solver speed: ILP vs heuristic")
        for r in rs:
            if "ilp_ms" in r:
                print(f"{r['problem']:28s} ilp {r['ilp_ms']:8.1f} ms "
                      f"(A={r['ilp_area']:.0f})   "
                      f"heur {r['heur_ms']:8.1f} ms (A={r['heur_area']:.0f})")
            else:
                print(f"{r['problem']:28s} {r['engine']:9s} "
                      f"{r['wall_ms']:8.1f} ms  chips={r['chips']:.0f} "
                      f"tok/s={r['tok_per_s']:,.0f}")
    return rs


if __name__ == "__main__":
    run()
