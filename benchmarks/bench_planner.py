"""Beyond-paper: the space/time planner on every assigned architecture.

For each arch x {train_4k, decode_32k}: plan on a one-pod budget (two pods
for the 400B-class), ILP vs heuristic, and compare the folded projection
against the naive uniform-TP16 policy the dry-run baselines use — the
planner's predicted speedup is the analytic motivation for the §Perf
hillclimb.
"""
from __future__ import annotations

from repro.configs import SHAPES, get_config
from repro.core import planner

ARCHS = [
    "mamba2-370m", "h2o-danube-3-4b", "deepseek-coder-33b", "nemotron-4-15b",
    "qwen2.5-3b", "jamba-1.5-large-398b", "llama4-maverick-400b-a17b",
    "llama4-scout-17b-a16e", "internvl2-26b", "seamless-m4t-medium",
]


def rows(shapes=("train_4k", "decode_32k")):
    out = []
    for arch in ARCHS:
        cfg = get_config(arch)
        budget = 512 if cfg.param_count() * 4 > 1e12 else 256
        for sname in shapes:
            shape = SHAPES[sname]
            rec = {"arch": arch, "shape": sname, "budget": budget}
            for eng in ("ilp", "heuristic"):
                try:
                    p = planner.plan(cfg, shape, chips=budget, engine=eng)
                    ex = planner.to_execution(p, cfg=cfg, chips=budget)
                    rec[f"{eng}_chips"] = p.total_chips
                    rec[f"{eng}_tok_s"] = p.tokens_per_s
                    rec[f"{eng}_feasible"] = p.feasible
                    if eng == "heuristic":
                        rec["plan_tp"] = ex.tp
                        f_plan = planner.folded_tokens_per_s(
                            cfg, shape, chips=budget, tp=ex.tp)
                        f_naive = planner.folded_tokens_per_s(
                            cfg, shape, chips=budget, tp=16)
                        rec["folded_plan_tok_s"] = f_plan["tokens_per_s"]
                        rec["folded_tp16_tok_s"] = f_naive["tokens_per_s"]
                        rec["plan_vs_tp16"] = (
                            f_plan["tokens_per_s"] / f_naive["tokens_per_s"]
                            if f_naive["tokens_per_s"] else float("inf"))
                except Exception as e:  # pragma: no cover
                    rec[f"{eng}_error"] = repr(e)[:80]
            out.append(rec)
    return out


def run(verbose=True):
    rs = rows()
    if verbose:
        print("# Planner on all assigned archs (budget = 1 pod; 2 for 400B)")
        print(f"{'arch':26s} {'shape':10s} {'heur chips':>10s} "
              f"{'tok/s':>13s} {'tp*':>4s} {'vs tp16':>8s}")
        for r in rs:
            if "heuristic_chips" not in r:
                print(f"{r['arch']:26s} {r['shape']:10s} "
                      f"ERR {r.get('heuristic_error', '?')}")
                continue
            print(f"{r['arch']:26s} {r['shape']:10s} "
                  f"{r['heuristic_chips']:10.0f} "
                  f"{r['heuristic_tok_s']:13,.0f} {r.get('plan_tp', 0):4d} "
                  f"{r.get('plan_vs_tp16', 0):8.2f}x")
    return rs


if __name__ == "__main__":
    run()
