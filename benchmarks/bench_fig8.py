"""Paper Fig. 8 / Eq. 9-14: fork/join tree overhead and combining savings."""
from __future__ import annotations

from repro.core.fork_join import (combined_tree_overhead_eq14,
                                  combining_savings, tree_overhead_eq9)


def rows(nf: int = 4):
    out = []
    nr = nf
    while nr <= 1024:
        e9 = tree_overhead_eq9(nr, nf)
        e14 = combined_tree_overhead_eq14(nr, nf)
        out.append({"nr": nr, "eq9": e9, "eq14": e14,
                    "saved": combining_savings(nr, nf),
                    "saved_frac": (e9 - e14) / e9 if e9 else 0.0})
        nr *= nf
    return out


def run(verbose=True):
    rs = rows()
    if verbose:
        print("# Fig 8 — fork-tree overhead: Eq. 9 vs combined Eq. 14 (nf=4)")
        print(f"{'nr':>5} {'eq9':>6} {'eq14':>6} {'saved':>6} {'frac':>6}")
        for r in rs:
            print(f"{r['nr']:5d} {r['eq9']:6d} {r['eq14']:6d} "
                  f"{r['saved']:6d} {r['saved_frac']:6.0%}")
        print("(paper: 'more than 75% overhead area saved' at nf=4)")
    return rs


if __name__ == "__main__":
    run()
