"""Paper §III.A: StreamIt kernels through the front-end + KPN simulator.

For FFT / FilterBank / Autocor: build the STG, enumerate implementations,
verify functional equivalence against numpy references, and report the
impl-library spread plus a timed-simulator throughput check of the
heuristic's selection (the paper: "a simulator has been implemented to
validate the results").
"""
from __future__ import annotations

import numpy as np

from repro.core import heuristic
from repro.core.fork_join import LITERAL
from repro.core.simulate import run, run_functional
from repro.core.stg import Selection
from repro.core.throughput import analyze
from repro.graphs import streamit


def _check(name, g, inputs, reference):
    sel = Selection.fastest(g)
    outs = run_functional(g, sel, inputs)
    sink = g.sinks()[0]
    got = outs[sink]
    ok = all(np.allclose(np.asarray(a), np.asarray(b))
             for a, b in zip(got, reference))
    n_impls = sum(len(g.nodes[n].impls) for n in g.nodes)
    # heuristic at 2x the fastest achievable rate
    v_fast = analyze(g, sel).v_app
    res = heuristic.min_area(g, 2 * v_fast, LITERAL)
    sim = run(g, res.selection, inputs)
    v_sim = sim.inverse_throughput(sink)
    return {"bench": name, "functional_ok": ok, "n_impls": n_impls,
            "v_fastest": v_fast, "heur_area": res.total_area,
            "heur_v_model": res.v_app if res.v_app else 0.0,
            "v_sim": v_sim}


def rows():
    out = []
    blocks8 = [np.random.default_rng(i).normal(size=8) for i in range(6)]
    blocks16 = [np.random.default_rng(i).normal(size=16) for i in range(6)]
    g = streamit.build_fft(8)
    out.append(_check("fft8", g, {"src": list(blocks8)},
                      streamit.fft_reference(blocks8)))
    g = streamit.build_filterbank(4, 8)
    out.append(_check("filterbank", g, {"src": list(blocks16)},
                      streamit.filterbank_reference(g, blocks16)))
    g = streamit.build_autocor(4, 16)
    out.append(_check("autocor", g, {"src": list(blocks16)},
                      streamit.autocor_reference(blocks16, 4)))
    return out


def run_bench(verbose=True):
    rs = rows()
    if verbose:
        print("# StreamIt front-end: impls found + simulator validation")
        print(f"{'bench':12s} {'func':>5s} {'#impl':>6s} {'v_fast':>7s} "
              f"{'heur_A':>7s} {'v_model':>8s} {'v_sim':>7s}")
        for r in rs:
            print(f"{r['bench']:12s} {str(r['functional_ok']):>5s} "
                  f"{r['n_impls']:6d} {r['v_fastest']:7.2f} "
                  f"{r['heur_area']:7.0f} {r['heur_v_model']:8.2f} "
                  f"{r['v_sim']:7.2f}")
    return rs


if __name__ == "__main__":
    run_bench()
