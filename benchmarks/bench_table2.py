"""Paper Table 2: ILP vs heuristic on the JPEG encoder at v in {1,2,4,8}."""
from __future__ import annotations

from repro.core import heuristic, ilp
from repro.core.fork_join import JPEG_CALIBRATED
from repro.graphs.jpeg import TABLE2_TOTALS, build_stg


def rows():
    g = build_stg()
    out = []
    for v in (1, 2, 4, 8):
        ri = ilp.min_area(g, v, JPEG_CALIBRATED)
        rh = heuristic.min_area(g, v, JPEG_CALIBRATED)
        pub_i, pub_h = TABLE2_TOTALS[v]
        out.append({
            "v_tgt": v,
            "ilp_area": ri.total_area, "ilp_pub": pub_i,
            "heur_area": rh.total_area, "heur_pub": pub_h,
            "saving_vs_our_ilp": 1 - rh.total_area / ri.total_area,
            "saving_vs_pub_ilp": 1 - rh.total_area / pub_i,
            "ilp_ms": ri.solve_seconds * 1e3,
            "heur_ms": rh.solve_seconds * 1e3,
        })
    return out


def run(verbose=True):
    rs = rows()
    if verbose:
        print("# Table 2 — JPEG: ILP vs heuristic (published totals in [])")
        print(f"{'v':>3} {'ILP':>8} {'[pub]':>8} {'heur':>8} {'[pub]':>8} "
              f"{'save':>6} {'save(pub)':>9}")
        for r in rs:
            print(f"{r['v_tgt']:3d} {r['ilp_area']:8.0f} [{r['ilp_pub']:6.0f}] "
                  f"{r['heur_area']:8.0f} [{r['heur_pub']:6.0f}] "
                  f"{r['saving_vs_our_ilp']:6.0%} {r['saving_vs_pub_ilp']:9.0%}")
    return rs


if __name__ == "__main__":
    run()
