"""Gradient-compression wire bytes: int8 ring vs f32 all-reduce.

Lowers both sync schemes for a 16-way data axis on simulated devices and
prices the collective traffic with the same HLO parser the roofline uses.
Expected: the quantized ring moves ~4x fewer bytes than an f32 ring
all-reduce (int8 payload both directions, ppermute chains).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.optim.compress import compressed_mean
    from repro.analysis import hlo as H

    mesh = jax.make_mesh((16,), ("data",))
    N = 1 << 22          # 4M f32 grads per device (16 MB)

    def ring(x):
        return compressed_mean(x[0], "data", 16)[None]

    def psum_mean(x):
        return (jax.lax.psum(x[0], "data") / 16)[None]

    import numpy as np
    xs = jax.ShapeDtypeStruct((16, N), jnp.float32)
    out = {}
    for name, fn in (("int8_ring", ring), ("f32_allreduce", psum_mean)):
        f = jax.jit(shard_map(fn, mesh=mesh, in_specs=P("data"),
                              out_specs=P("data"), check_rep=False))
        txt = f.lower(xs).compile().as_text()
        coll = H.collect(txt, 16)
        out[name] = coll.total()
        print(f"{name:14s} wire={coll.total()/1e6:10.1f} MB  "
              f"{ {k: round(v/1e6,1) for k,v in coll.wire_bytes.items()} }")
    print(f"ratio f32/int8 = {out['f32_allreduce']/out['int8_ring']:.2f}x")
""")


def run(verbose=True):
    r = subprocess.run([sys.executable, "-c", _SCRIPT],
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    if verbose:
        print("# int8 ring reduce-scatter+all-gather vs f32 all-reduce "
              "(16-way, 16MB grads)")
        print(r.stdout.strip() or r.stderr[-1500:])
    assert r.returncode == 0, r.stderr[-1500:]
    return r.stdout


if __name__ == "__main__":
    run()
