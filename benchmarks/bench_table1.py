"""Paper Table 1: the JPEG implementation library.

The paper's Intra-Node Optimizer finds 11/17/11/1 implementations for the
four kernels; Table 1 prints a selection.  We carry the published library
verbatim (graphs/jpeg.py TABLE1) and check its area*v products (a
pipelined/expanded implementation trades area for II roughly linearly —
the library's own consistency claim), plus run our intra-node enumerator
on the N-body composite body to show the same enumeration machinery.
"""
from __future__ import annotations

from repro.graphs.jpeg import TABLE1


def rows():
    out = []
    for mod, lib in TABLE1.items():
        for (name, v, area) in lib:
            out.append({"module": mod, "impl": name, "v": v, "area": area,
                        "area_x_v": area * v})
    return out


def run(verbose=True):
    rs = rows()
    if verbose:
        print("# Table 1 — JPEG implementation library (published, carried)")
        cur = None
        for r in rs:
            if r["module"] != cur:
                cur = r["module"]
                print(f"{cur}:")
            print(f"   {r['impl']:4s} v={r['v']:4g} area={r['area']:5g} "
                  f"(area*v={r['area_x_v']:6g})")
    return rs


if __name__ == "__main__":
    run()
