"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads artifacts/dryrun/*.json (produced by ``repro.launch.dryrun``) and
prints, per (arch x shape x mesh): the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS, MFU bound, and what would move the
dominant term (heuristic advice string).
"""
from __future__ import annotations

import json
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def _advice(r) -> str:
    b = r["bottleneck"]
    if b == "collective":
        return "reduce TP degree / EP all-to-all dispatch / seq-shard cache"
    if b == "memory":
        return "larger microbatch or fused kernels (raise arithmetic intensity)"
    return "near compute roofline: overlap collectives, tune remat"


def rows(mesh="16x16"):
    out = []
    for f in sorted(ART.glob("*.json")):
        d = json.loads(f.read_text())
        if mesh is not None and d["mesh"] != mesh:
            continue
        if d.get("variant", "baseline") != "baseline":
            continue          # §Perf variants are reported separately
        r = d["roofline"]
        out.append({
            "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "bottleneck": r["bottleneck"],
            "model_flops": r["model_flops"], "hlo_flops": r["hlo_flops"],
            "useful": r["useful_flops_ratio"], "mfu_bound": r["mfu"],
            "tokens_per_s": r["tokens_per_s"],
            "gb_per_dev": (r.get("per_device_peak_memory") or 0) / 1e9,
            "advice": _advice(r),
        })
    return out


def run(verbose=True, mesh="16x16"):
    rs = rows(mesh)
    if verbose:
        print(f"# Roofline per cell (mesh {mesh}; terms in seconds)")
        print(f"{'arch':26s} {'shape':11s} {'comp':>7s} {'mem':>7s} "
              f"{'coll':>8s} {'bneck':6s} {'MFU':>6s} {'useful':>6s}")
        for r in rs:
            print(f"{r['arch']:26s} {r['shape']:11s} {r['compute_s']:7.3f} "
                  f"{r['memory_s']:7.3f} {r['collective_s']:8.3f} "
                  f"{r['bottleneck'][:6]:6s} {r['mfu_bound']:6.3f} "
                  f"{r['useful']:6.2f}")
        n = len(rs)
        if n:
            from collections import Counter
            c = Counter(r["bottleneck"] for r in rs)
            print(f"\n{n} cells; bottleneck mix: {dict(c)}")
    return rs


if __name__ == "__main__":
    run()
