"""Paper Figure 4: inverse-throughput/area curve for the N-body force node.

The Intra-Node Optimizer enumerates schedules of the pipelined force
calculation (Fig. 2) between full expansion (v=1, the Fig. 3 pipeline) and
a single PE (v=33 = sum of op latencies).  The paper's anchor points:
v=1 fastest, v=33 area=1, and "replicating the slowest implementation into
33 copies or using the fastest directly" both reach v=1.
"""
from __future__ import annotations

from repro.core.intra_node import enumerate_impls
from repro.graphs.nbody import FORCE_BODY


def rows():
    impls = enumerate_impls(FORCE_BODY)
    return [{"impl": im.name, "v": im.ii, "area": im.area} for im in impls]


def run(verbose=True):
    rs = rows()
    if verbose:
        print("# Fig 4 — N-body force implementations (intra-node optimizer)")
        print(f"{'v':>6} {'area':>6}")
        for r in rs:
            print(f"{r['v']:6g} {r['area']:6g}")
        vs = [r["v"] for r in rs]
        print(f"v range: {min(vs):g}..{max(vs):g} "
              f"({len(rs)} pareto implementations)")
    return rs


if __name__ == "__main__":
    run()
