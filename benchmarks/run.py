"""Benchmark harness: one module per paper table/figure + system benches.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run table2 roofline
    PYTHONPATH=src python -m benchmarks.run pipeline --json-dir artifacts
    PYTHONPATH=src python -m benchmarks.run pipeline --smoke --json-dir a

``--json-dir DIR`` writes each bench's rows to ``DIR/BENCH_<name>.json``
(benches whose runners return rows / accept ``json_path``).  CI uploads
the directory as an artifact so the perf trajectory accumulates run over
run instead of living only in job logs.  ``--smoke`` forwards to benches
whose runners accept it (fast PR-CI subsets; others run in full).
"""
from __future__ import annotations

import inspect
import json
import os
import sys
import time

BENCHES = [
    ("table1", "bench_table1", "run"),
    ("table2", "bench_table2", "run"),
    ("fig4", "bench_fig4", "run"),
    ("fig8", "bench_fig8", "run"),
    ("streamit", "bench_streamit", "run_bench"),
    ("solver_speed", "bench_solver_speed", "run"),
    ("compress", "bench_compress", "run"),
    ("planner", "bench_planner", "run"),
    ("roofline", "bench_roofline", "run"),
    ("pipeline", "bench_pipeline", "run"),
    ("serve", "bench_serve", "run"),
]


def _invoke(fn, name: str, json_dir: str | None, smoke: bool = False):
    """Run one bench; route rows to BENCH_<name>.json when a dir is set."""
    kwargs = {"verbose": True}
    params = inspect.signature(fn).parameters
    if smoke and "smoke" in params:
        kwargs["smoke"] = True
    json_path = (os.path.join(json_dir, f"BENCH_{name}.json")
                 if json_dir else None)
    if json_path and "json_path" in params:
        kwargs["json_path"] = json_path
        json_path = None                   # the bench writes it itself
    out = fn(**kwargs)
    if json_path and out is not None:
        try:
            # serialise fully before touching the file so a mid-stream
            # TypeError cannot leave a truncated artifact for CI to upload
            payload = json.dumps(out, indent=2, default=str)
        except TypeError as e:
            print(f"skipping {json_path}: return value not "
                  f"JSON-serialisable ({e})")
            return
        with open(json_path, "w") as f:
            f.write(payload)
        print(f"wrote {json_path}")


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    json_dir = None
    smoke = "--smoke" in argv
    if smoke:
        argv = [a for a in argv if a != "--smoke"]
    if "--json-dir" in argv:
        i = argv.index("--json-dir")
        if i + 1 >= len(argv):
            raise SystemExit("usage: benchmarks.run [names...] --json-dir DIR")
        json_dir = argv[i + 1]
        argv = argv[:i] + argv[i + 2:]
        os.makedirs(json_dir, exist_ok=True)
    wanted = set(argv) if argv else None
    failures = []
    for name, mod_name, fn_name in BENCHES:
        if wanted is not None and name not in wanted:
            continue
        print()
        print("#" * 72)
        print(f"## bench: {name}")
        print("#" * 72)
        t0 = time.perf_counter()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=[fn_name])
            _invoke(getattr(mod, fn_name), name, json_dir, smoke)
            print(f"[{name}: {time.perf_counter()-t0:.1f}s]")
        except Exception as e:
            failures.append((name, repr(e)))
            import traceback
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")
    print("\nall benchmarks completed")


if __name__ == "__main__":
    main()
