"""Benchmark harness: one module per paper table/figure + system benches.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run table2 roofline
"""
from __future__ import annotations

import sys
import time

BENCHES = [
    ("table1", "bench_table1", "run"),
    ("table2", "bench_table2", "run"),
    ("fig4", "bench_fig4", "run"),
    ("fig8", "bench_fig8", "run"),
    ("streamit", "bench_streamit", "run_bench"),
    ("solver_speed", "bench_solver_speed", "run"),
    ("compress", "bench_compress", "run"),
    ("planner", "bench_planner", "run"),
    ("roofline", "bench_roofline", "run"),
    ("pipeline", "bench_pipeline", "run"),
]


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    wanted = set(argv) if argv else None
    failures = []
    for name, mod_name, fn_name in BENCHES:
        if wanted is not None and name not in wanted:
            continue
        print()
        print("#" * 72)
        print(f"## bench: {name}")
        print("#" * 72)
        t0 = time.perf_counter()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=[fn_name])
            getattr(mod, fn_name)(verbose=True)
            print(f"[{name}: {time.perf_counter()-t0:.1f}s]")
        except Exception as e:
            failures.append((name, repr(e)))
            import traceback
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")
    print("\nall benchmarks completed")


if __name__ == "__main__":
    main()
