"""Planned-vs-measured throughput of the streaming executor.

For each workload: solve the trade-off, execute the plan as a real
pipeline (`runtime.pipeline`), and report the plan's promised inverse
throughput against what the pipeline sustained — as a table and as JSON
(the CI artifact consumed by regression tooling).  The schedule rows A/B
plain 1F1B against interleaved 1F1B under the virtual-clock driver
(`schedule.simulate_schedule`): measured bubble fraction vs the
`schedule.interleaved_bubble` analytic ceiling, on the same physical
stage count and per-microbatch work.

``--smoke`` runs the fast subset (interpreter + schedule rows, no jax
pipeline) — the PR-CI mode that keeps schedule regressions visible in
BENCH_pipeline.json without paying for the full sweep.

    PYTHONPATH=src python -m benchmarks.bench_pipeline [--json out.json]
                                                       [--smoke]
"""
from __future__ import annotations

import json
import sys

import numpy as np


def _jpeg_rows():
    from repro.core import heuristic
    from repro.core.fork_join import JPEG_CALIBRATED
    from repro.core.stg import Selection
    from repro.core.throughput import analyze
    from repro.graphs import jpeg
    from repro.runtime.pipeline import (Tracer, compare, execute,
                                        stall_bottleneck)

    g = jpeg.build_stg()
    blocks = jpeg.random_blocks(256)
    rows = []
    sels = {
        "fastest": Selection.fastest(g),
        "smallest": Selection.smallest(g),
        "solver_v8": heuristic.min_area(g, 8, JPEG_CALIBRATED).selection,
        "solver_v2": heuristic.min_area(g, 2, JPEG_CALIBRATED).selection,
    }
    for name, sel in sels.items():
        # the virtual clock is deterministic: tracing the measured run
        # itself costs nothing and cannot move the cycle counts
        tr = Tracer()
        run = execute(g, sel, {"camera": blocks}, fj=JPEG_CALIBRATED,
                      tracer=tr)
        rep = compare(g, sel, run)
        rows.append({
            "workload": f"jpeg/{name}",
            "path": "interpreter",
            "v_planned": analyze(g, sel).v_app,
            "v_measured": rep.v_app_measured,
            "accuracy": rep.accuracy,
            "bottleneck": rep.bottleneck_measured,
            "stall_bottleneck": stall_bottleneck(tr),
            "per_stage_stall_cycles": {
                s: m.stall_v for s, m in rep.stages.items()},
            "per_stage_starve_cycles": {
                s: m.starve_v for s, m in rep.stages.items()},
            "fifo_stalls": rep.fifo_stalls,
        })
    return rows


def _streamit_rows():
    from repro.core import heuristic
    from repro.core.fork_join import LITERAL
    from repro.core.throughput import analyze
    from repro.graphs import streamit
    from repro.runtime.pipeline import compare, execute

    rng = np.random.default_rng(0)
    rows = []
    for bname, build, n_in in (("fft", streamit.build_fft, 8),
                               ("filterbank", streamit.build_filterbank, 16),
                               ("autocor", streamit.build_autocor, 16)):
        g = build()
        sel = heuristic.min_area(g, 4, LITERAL).selection
        blocks = [rng.normal(size=n_in) for _ in range(128)]
        run = execute(g, sel, {"src": blocks}, fj=LITERAL)
        rep = compare(g, sel, run)
        rows.append({
            "workload": f"streamit/{bname}",
            "path": "interpreter",
            "v_planned": analyze(g, sel).v_app,
            "v_measured": rep.v_app_measured,
            "accuracy": rep.accuracy,
            "bottleneck": rep.bottleneck_measured,
            "fifo_stalls": rep.fifo_stalls,
        })
    return rows


def _lm_rows():
    import jax.numpy as jnp
    from repro.configs.base import ShapeCfg
    from repro.configs.tiny import CONFIG as tiny
    from repro.core import planner
    from repro.graphs import lm_graph
    from repro.runtime.pipeline import (LMPipeline, fill_drain_bubble,
                                        selection_from_plan)

    shape = ShapeCfg("bench_pipe", 32, 8, "train")
    plan = planner.plan(tiny, shape, chips=16, max_tp=4)
    stg, info = lm_graph.build_stg(tiny, shape, max_tp=4)
    pipe = LMPipeline(tiny, stg, selection_from_plan(plan))
    rng = np.random.default_rng(0)
    mbs = [jnp.asarray(rng.integers(0, tiny.vocab, (2, 32)), jnp.int32)
           for _ in range(12)]
    pipe.run(mbs[:2])                     # warm the jit caches
    pipe.run(mbs[:2], overlap=False)
    # overlap A/B: median of 3 runs each (host wall clock is noisy); the
    # async executor must strictly beat the serial one on the same graph
    walls: dict[bool, list[float]] = {True: [], False: []}
    res_by: dict[bool, object] = {}
    for _ in range(3):
        for ov in (True, False):
            r = pipe.run(mbs, overlap=ov)
            walls[ov].append(r.wall_s)
            res_by[ov] = r
    wall_on = sorted(walls[True])[1]
    wall_off = sorted(walls[False])[1]
    res = res_by[True]
    assert pipe.compile_stats.late == 0, \
        f"compiles landed inside a timed run: {pipe.compile_stats.summary()}"
    toks_per_mb = 2 * 32
    bubble = fill_drain_bubble(pipe.n_stages, len(mbs))
    return [{
        "workload": "lm/tiny",
        "path": "jax",
        "planned_tokens_per_s": plan.tokens_per_s,      # v5e roofline promise
        "measured_tokens_per_s": res.tokens_per_s(toks_per_mb),  # host CPU
        "overlap_on_wall_s": wall_on,
        "overlap_off_wall_s": wall_off,
        # share of the serial wall the async executor gave back, against
        # the analytic fill-drain bubble ceiling for this (stages, mbs)
        "recovered_bubble_pct": 100.0 * (wall_off - wall_on) / wall_off,
        "bubble_ceiling_pct": 100.0 * bubble,
        "oversubscription": res.placement.oversubscription,
        "per_stage_us": {s.name: res.stage_inverse_us(s.name)
                         for s in pipe.stages},
        # host dispatch overhead per firing, kept apart from stage II so
        # dispatch-side regressions are data, not noise inside measured v
        "per_stage_host_us": {s.name: res.stage_host_us(s.name)
                              for s in pipe.stages},
        "compile_stats": pipe.compile_stats.summary(),
        "note": "planned assumes HW_V5E chips; measured is host-CPU "
                "wall clock — compare shapes, not magnitudes",
    }]


def _schedule_rows(n_micro: int = 16):
    """1F1B vs interleaved bubble A/B under the virtual clock: same
    physical stage count, same per-microbatch work per stage (plain ops
    cost v chunk-units; interleaved ops cost 1), measured against the
    `schedule.interleaved_bubble` analytic ceilings."""
    from repro.runtime.pipeline import (interleaved_1f1b, interleaved_bubble,
                                        one_f_one_b, simulate_schedule)

    rows = []
    for p, v in ((4, 2), (4, 4), (8, 2)):
        m = n_micro if n_micro % p == 0 else p * max(1, n_micro // p)
        plain = simulate_schedule(one_f_one_b(p, m), f_cost=float(v))
        ilv = simulate_schedule(interleaved_1f1b(p, m, v))
        rows.append({
            "workload": f"schedule/p{p}_m{m}_v{v}",
            "path": "virtual",
            "bubble_1f1b": plain.bubble,
            "bubble_1f1b_ceiling": interleaved_bubble(p, m, 1),
            "bubble_interleaved": ilv.bubble,
            "bubble_interleaved_ceiling": interleaved_bubble(p, m, v),
            "interleaved_wins": ilv.bubble < plain.bubble,
            "makespan_1f1b": plain.makespan,
            "makespan_interleaved": ilv.makespan,
        })
    return rows


def run(verbose: bool = True, json_path: str | None = None,
        smoke: bool = False) -> list[dict]:
    rows = _jpeg_rows() + _schedule_rows()
    if not smoke:
        rows += _streamit_rows() + _lm_rows()
    if verbose:
        for r in rows:
            if r["path"] == "interpreter":
                print(f"{r['workload']:24s} planned v={r['v_planned']:8.3f} "
                      f"measured v={r['v_measured']:8.3f} "
                      f"(x{r['accuracy']:.3f})  bottleneck={r['bottleneck']}")
            elif r["path"] == "virtual":
                print(f"{r['workload']:24s} bubble 1f1b "
                      f"{100 * r['bubble_1f1b']:.1f}% (ceiling "
                      f"{100 * r['bubble_1f1b_ceiling']:.1f}%) | interleaved "
                      f"{100 * r['bubble_interleaved']:.1f}% (ceiling "
                      f"{100 * r['bubble_interleaved_ceiling']:.1f}%)")
            else:
                print(f"{r['workload']:24s} planned {r['planned_tokens_per_s']:,.0f} tok/s "
                      f"(v5e) | measured {r['measured_tokens_per_s']:,.0f} tok/s (host) | "
                      f"overlap on/off {r['overlap_on_wall_s']:.3f}s/"
                      f"{r['overlap_off_wall_s']:.3f}s "
                      f"(recovered {r['recovered_bubble_pct']:+.1f}% of wall, "
                      f"bubble ceiling {r['bubble_ceiling_pct']:.1f}%)")
        print(json.dumps(rows, indent=2))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=2)
        if verbose:
            print(f"wrote {json_path}")
    return rows


if __name__ == "__main__":
    path = None
    if "--json" in sys.argv:
        i = sys.argv.index("--json") + 1
        if i >= len(sys.argv):
            sys.exit("usage: bench_pipeline [--json PATH] [--smoke]")
        path = sys.argv[i]
    run(verbose=True, json_path=path, smoke="--smoke" in sys.argv)
